//! Event-driven TCP transport: one readiness thread serving many
//! connections over non-blocking sockets, speaking **both** wire
//! protocols on the same port.
//!
//! Where [`super::server`] spends a blocking OS thread per connection,
//! this transport multiplexes every connection over a single
//! readiness-driven event loop ([`crate::util::readiness`] — epoll on
//! Linux, `poll(2)` elsewhere, std only, no async runtime) and hands
//! actual request execution to the existing worker pool:
//!
//! * **Protocol auto-detection, per message.** The first unconsumed byte
//!   of each message picks the decoder: [`frame::FRAME_MAGIC`] (`0xFB`)
//!   opens a binary frame, anything else is a JSON text line. A single
//!   connection may interleave both; JSON-line clients and golden flows
//!   keep working unchanged.
//! * **Admission batching.** One readable wakeup drains *all* complete
//!   messages a socket has buffered and submits them to the pool as one
//!   batch ([`Coordinator::submit_jobs`] →
//!   [`super::backpressure::Admission::submit_batch`]), paying dispatch
//!   bookkeeping once per wakeup instead of once per request.
//! * **Out-of-order completion.** Binary responses flush the moment a
//!   worker finishes them, keyed by the client-assigned request id. JSON
//!   responses are re-sequenced through a per-connection reorder buffer
//!   so line-protocol clients keep their in-order contract.
//! * **Coalesced vectored writes.** Completed responses queue per
//!   connection and leave in a single `write_vectored` per flush. Blob
//!   responses (`sketch_fetch_bin`) queue as spliced buffer runs — the
//!   codec bytes are never copied into a contiguous frame.
//! * **Bounded buffers.** Read buffers are capped at one max frame;
//!   a connection with too many requests in flight or too many unsent
//!   response bytes stops being read until it drains (per-connection
//!   backpressure that never blocks the event thread).
//!
//! Workers hand finished responses back through a completion channel +
//! self-pipe wakeup ([`super::worker::Reply::Callback`] encodes the
//! response bytes on the worker thread, so the event thread only moves
//! buffers).
//!
//! Observability: `transport.frames_in/out`, `transport.bytes_in/out`,
//! `transport.batches` counters and `transport.batch_size.{min,mean,max}`
//! gauges, all visible through the ordinary `metrics` op.

use super::frame::{self, FrameMsg, FrameStatus};
use super::protocol::{self, Request, Response};
use super::service::Coordinator;
use super::worker::{Job, Reply};
use crate::util::readiness::{make_backend, Readiness, ReadinessBackend};
use std::collections::{BTreeMap, VecDeque};
use std::io::{IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Poll timeout — the shutdown flag is observed at least this often.
const IDLE_POLL_MS: i32 = 50;
/// Shutdown drains in-flight work for at most this many idle polls (~2s).
const SHUTDOWN_DRAIN_POLLS: u32 = 40;
/// A connection may buffer at most one maximum-size message.
const MAX_RBUF: usize = frame::HEADER_LEN + frame::MAX_PAYLOAD + 64;
/// Per-connection in-flight request cap: reads pause above this.
const MAX_INFLIGHT: usize = 1024;
/// Per-connection unsent response bytes cap: reads pause above this.
const MAX_WBUF_BYTES: usize = 8 << 20;
/// Max buffers per vectored write (typical IOV_MAX is far higher; this
/// just bounds the stack slice array).
const MAX_IOV: usize = 64;
/// Readiness keys: listener, wake pipe, then connection slots.
const KEY_LISTENER: usize = 0;
const KEY_WAKE: usize = 1;
const KEY_CONN0: usize = 2;

/// How a response must leave the connection: binary frames carry their
/// request id and may complete out of order; JSON lines are re-sequenced.
#[derive(Debug, Clone, Copy)]
enum Token {
    Binary { id: u64 },
    Json { seq: u64 },
}

/// A finished response, already encoded to wire bytes by the worker. The
/// payload is a buffer *sequence*: blob-bearing binary responses arrive
/// as `[prefix, codec blob, trailer]` from the splicing encoder, queued
/// as-is and joined by the vectored flush — the blob bytes the worker
/// encoded are the bytes the socket sends, never re-buffered.
struct Completion {
    conn: usize,
    gen: u64,
    token: Token,
    payload: Vec<Vec<u8>>,
}

struct Conn {
    stream: TcpStream,
    gen: u64,
    rbuf: Vec<u8>,
    rpos: usize,
    wqueue: VecDeque<Vec<u8>>,
    /// Bytes of `wqueue.front()` already written (partial-write cursor).
    woff: usize,
    /// Total unsent bytes across `wqueue`.
    wbytes: usize,
    inflight: usize,
    /// Next sequence number assigned to an admitted JSON-line request.
    json_next_submit: u64,
    /// Next sequence number allowed to flush (in-order contract).
    json_next_flush: u64,
    json_pending: BTreeMap<u64, Vec<u8>>,
    /// EOF seen (or shutdown): stop reading, flush what's owed, close.
    closing: bool,
    /// Interest last pushed to the readiness backend (read, write) —
    /// re-registration happens only when this changes.
    interest: (bool, bool),
}

impl Conn {
    fn new(stream: TcpStream, gen: u64) -> Conn {
        Conn {
            stream,
            gen,
            rbuf: Vec::new(),
            rpos: 0,
            wqueue: VecDeque::new(),
            woff: 0,
            wbytes: 0,
            inflight: 0,
            json_next_submit: 0,
            json_next_flush: 0,
            json_pending: BTreeMap::new(),
            closing: false,
            interest: (false, false),
        }
    }

    fn push_write(&mut self, payload: Vec<u8>) {
        // Empty buffers (an empty spliced blob) carry nothing and would
        // make `flush` misread socket pushback as a dead peer.
        if payload.is_empty() {
            return;
        }
        self.wbytes += payload.len();
        self.wqueue.push_back(payload);
    }

    /// Sequence a completed JSON response, releasing every consecutive
    /// line that is now allowed to leave.
    fn sequence_json(&mut self, seq: u64, payload: Vec<u8>) {
        self.json_pending.insert(seq, payload);
        while let Some(buf) = self.json_pending.remove(&self.json_next_flush) {
            self.json_next_flush += 1;
            self.push_write(buf);
        }
    }

    /// Too much in flight or unsent: stop reading until it drains.
    fn throttled(&self) -> bool {
        self.inflight >= MAX_INFLIGHT || self.wbytes >= MAX_WBUF_BYTES
    }

    /// Nothing owed to the peer: a closing connection may be dropped.
    fn drained(&self) -> bool {
        self.inflight == 0 && self.json_pending.is_empty() && self.wqueue.is_empty()
    }

    /// Coalesce queued responses into vectored writes until the socket
    /// pushes back. Returns bytes written, or `Err` on a dead socket.
    fn flush(&mut self) -> std::io::Result<usize> {
        let mut written = 0usize;
        while !self.wqueue.is_empty() {
            let n = {
                let mut slices: Vec<IoSlice> =
                    Vec::with_capacity(self.wqueue.len().min(MAX_IOV));
                for (i, buf) in self.wqueue.iter().take(MAX_IOV).enumerate() {
                    slices.push(IoSlice::new(if i == 0 { &buf[self.woff..] } else { &buf[..] }));
                }
                match (&self.stream).write_vectored(&slices) {
                    Ok(0) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::WriteZero,
                            "socket accepted zero bytes",
                        ))
                    }
                    Ok(n) => n,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            };
            written += n;
            self.wbytes -= n;
            let mut n = n;
            while n > 0 {
                let front_left = self.wqueue.front().expect("bytes imply a buffer").len()
                    - self.woff;
                if n >= front_left {
                    n -= front_left;
                    self.wqueue.pop_front();
                    self.woff = 0;
                } else {
                    self.woff += n;
                    n = 0;
                }
            }
        }
        Ok(written)
    }
}

/// Running admission-batch statistics, published as gauges per batch.
struct BatchStats {
    min: u64,
    max: u64,
    sum: u64,
    batches: u64,
}

impl BatchStats {
    fn new() -> BatchStats {
        BatchStats { min: u64::MAX, max: 0, sum: 0, batches: 0 }
    }
}

/// The event-driven server handle. `start` binds and spawns the loop;
/// `stop` drains in-flight work and joins it.
pub struct EventServer {
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    wake: Arc<UnixStream>,
    handle: Option<JoinHandle<()>>,
}

impl EventServer {
    pub fn start(coord: Arc<Coordinator>, addr: &str) -> anyhow::Result<EventServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (wake_tx, wake_rx) = UnixStream::pair()?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        let wake_tx = Arc::new(wake_tx);
        let (comp_tx, comp_rx) = channel();
        let mut el = EventLoop {
            coord,
            listener,
            shutdown: shutdown.clone(),
            wake_rx,
            wake_tx: wake_tx.clone(),
            comp_tx,
            comp_rx,
            conns: Vec::new(),
            free: Vec::new(),
            next_gen: 1,
            batch: BatchStats::new(),
            backend: make_backend(),
        };
        let handle = std::thread::Builder::new()
            .name("fastgm-event-loop".into())
            .spawn(move || el.run())?;
        Ok(EventServer { addr, shutdown, wake: wake_tx, handle: Some(handle) })
    }

    /// Stop accepting, drain in-flight responses (bounded), join the loop.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = (&*self.wake).write(&[1]);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct EventLoop {
    coord: Arc<Coordinator>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    wake_rx: UnixStream,
    wake_tx: Arc<UnixStream>,
    comp_tx: Sender<Completion>,
    comp_rx: Receiver<Completion>,
    /// Connection slab: stable ids while live, slots recycled through
    /// `free` with a fresh generation so stale completions can't cross
    /// into a successor connection.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    next_gen: u64,
    batch: BatchStats,
    /// Readiness notifier (epoll on Linux, poll elsewhere — see
    /// [`crate::util::readiness`]). Interest lives in the backend between
    /// wakeups; the loop pushes deltas instead of rebuilding an
    /// O(connections) descriptor array per iteration.
    backend: Box<dyn ReadinessBackend>,
}

impl EventLoop {
    fn run(&mut self) {
        log::info!("event transport readiness backend: {}", self.backend.name());
        if let Err(e) = self
            .backend
            .update(self.listener.as_raw_fd(), KEY_LISTENER, true, false)
            .and_then(|()| self.backend.update(self.wake_rx.as_raw_fd(), KEY_WAKE, true, false))
        {
            log::error!("event loop registration failed: {e}");
            return;
        }
        let mut drain_polls = 0u32;
        let mut accepting = true;
        let mut ready: Vec<Readiness> = Vec::new();
        loop {
            let draining = self.shutdown.load(Ordering::SeqCst);
            if draining {
                // Stop reading everywhere; finish what's owed.
                for conn in self.conns.iter_mut().flatten() {
                    conn.closing = true;
                }
                if accepting {
                    accepting = false;
                    let _ = self.backend.update(
                        self.listener.as_raw_fd(),
                        KEY_LISTENER,
                        false,
                        false,
                    );
                }
                self.reap_drained();
                if self.conns.iter().all(|c| c.is_none()) || drain_polls > SHUTDOWN_DRAIN_POLLS {
                    return;
                }
                drain_polls += 1;
            }

            // Push interest deltas, then wait: only changed connections
            // touch the backend, and an epoll wakeup reports just the
            // ready descriptors.
            self.refresh_interest();
            if let Err(e) = self.backend.wait(IDLE_POLL_MS, &mut ready) {
                log::error!("event loop wait failed: {e}");
                return;
            }
            let wake_ready = ready.iter().any(|r| r.key == KEY_WAKE && r.readable);
            let accept_ready = ready.iter().any(|r| r.key == KEY_LISTENER && r.readable);

            // Wake pipe: swallow the pending bytes (level-triggered).
            if wake_ready {
                let mut sink = [0u8; 256];
                while matches!((&self.wake_rx).read(&mut sink), Ok(n) if n > 0) {}
            }

            // Worker completions → per-connection write queues.
            while let Ok(c) = self.comp_rx.try_recv() {
                self.apply_completion(c);
            }

            if !draining && accept_ready {
                self.accept_ready();
            }

            // Readable connections: drain socket → parse all complete
            // messages → submit as ONE admission batch.
            for r in &ready {
                if r.key >= KEY_CONN0 && r.readable {
                    self.service_readable(r.key - KEY_CONN0);
                }
            }

            // Flush everything with queued bytes (not just write-ready
            // hits: completions may have landed after the wait).
            for id in 0..self.conns.len() {
                self.service_writable(id);
            }
            self.reap_drained();
        }
    }

    /// Re-arm the backend for every connection whose desired interest
    /// changed since the last push. Steady state is a boolean scan — no
    /// syscalls, no descriptor-array rebuild.
    fn refresh_interest(&mut self) {
        for (id, slot) in self.conns.iter_mut().enumerate() {
            let Some(conn) = slot else { continue };
            let want = (!conn.closing && !conn.throttled(), !conn.wqueue.is_empty());
            if conn.interest == want {
                continue;
            }
            conn.interest = want;
            if let Err(e) =
                self.backend.update(conn.stream.as_raw_fd(), KEY_CONN0 + id, want.0, want.1)
            {
                log::debug!("interest update failed, closing: {e}");
                conn.closing = true;
            }
        }
    }

    fn metrics(&self) -> &crate::coordinator::metrics::Metrics {
        self.coord.node().metrics()
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let gen = self.next_gen;
                    self.next_gen += 1;
                    let conn = Conn::new(stream, gen);
                    match self.free.pop() {
                        Some(id) => self.conns[id] = Some(conn),
                        None => self.conns.push(Some(conn)),
                    }
                    self.publish_conn_gauge();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("accept failed: {e}");
                    return;
                }
            }
        }
    }

    fn publish_conn_gauge(&self) {
        let live = self.conns.iter().filter(|c| c.is_some()).count();
        self.metrics().gauge_set("transport.connections", live as f64);
    }

    fn close_conn(&mut self, id: usize) {
        if let Some(conn) = self.conns[id].take() {
            self.backend.remove(conn.stream.as_raw_fd());
            self.free.push(id);
            self.publish_conn_gauge();
        }
    }

    fn apply_completion(&mut self, c: Completion) {
        let Some(conn) = self.conns.get_mut(c.conn).and_then(Option::as_mut) else { return };
        if conn.gen != c.gen {
            return; // stale: slot was recycled for a newer connection
        }
        conn.inflight -= 1;
        let is_frame = matches!(c.token, Token::Binary { .. });
        match c.token {
            // The loop is single-threaded, so a multi-buffer (spliced)
            // frame enqueues contiguously — nothing can interleave.
            Token::Binary { .. } => {
                for buf in c.payload {
                    conn.push_write(buf);
                }
            }
            Token::Json { seq } => {
                let mut bufs = c.payload.into_iter();
                let buf = bufs.next().unwrap_or_default();
                debug_assert!(bufs.next().is_none(), "JSON responses are single-buffer");
                conn.sequence_json(seq, buf);
            }
        }
        if is_frame {
            self.coord.node().metrics().incr("transport.frames_out");
        }
    }

    /// Drain the socket, parse every complete message, submit the batch.
    fn service_readable(&mut self, id: usize) {
        let mut chunk = [0u8; 64 * 1024];
        let mut read_total = 0usize;
        let mut fatal = false;
        {
            let Some(conn) = self.conns.get_mut(id).and_then(Option::as_mut) else { return };
            loop {
                match (&conn.stream).read(&mut chunk) {
                    Ok(0) => {
                        conn.closing = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&chunk[..n]);
                        read_total += n;
                        if conn.rbuf.len() - conn.rpos > MAX_RBUF {
                            log::warn!("connection exceeded {MAX_RBUF}-byte message cap");
                            fatal = true;
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        fatal = true;
                        break;
                    }
                }
            }
        }
        if read_total > 0 {
            self.metrics().add("transport.bytes_in", read_total as u64);
        }
        if fatal {
            self.close_conn(id);
            return;
        }
        let jobs = self.parse_messages(id);
        if self.conns.get(id).map(|c| c.is_none()).unwrap_or(true) {
            // Parsing hit unrecoverable framing corruption and closed the
            // connection; jobs already admitted still complete (their
            // completions will be dropped as stale).
            if !jobs.is_empty() {
                self.submit_batch(jobs);
            }
            return;
        }
        if !jobs.is_empty() {
            self.submit_batch(jobs);
        }
        // Eager flush: the socket is usually writable right now.
        self.service_writable(id);
    }

    /// Parse every complete message buffered on `id`, building worker
    /// jobs. Per-message errors (bad JSON, a client-sent response frame)
    /// are answered locally; framing corruption closes the connection —
    /// a binary stream with an untrusted length prefix cannot resync.
    fn parse_messages(&mut self, id: usize) -> Vec<Job> {
        let mut jobs = Vec::new();
        let mut local: Vec<(Token, Response)> = Vec::new();
        let mut frames_in = 0u64;
        let mut fatal = false;
        {
            let Some(conn) = self.conns.get_mut(id).and_then(Option::as_mut) else {
                return jobs;
            };
            let (conn_id, gen) = (id, conn.gen);
            loop {
                let buf = &conn.rbuf[conn.rpos..];
                if buf.is_empty() {
                    break;
                }
                if buf[0] == frame::FRAME_MAGIC {
                    match frame::decode_frame(buf) {
                        Ok(FrameStatus::Incomplete) => break,
                        Ok(FrameStatus::Frame { consumed, id: req_id, msg }) => {
                            conn.rpos += consumed;
                            frames_in += 1;
                            match msg {
                                FrameMsg::Request(request) => {
                                    conn.inflight += 1;
                                    jobs.push(make_job(
                                        &self.comp_tx,
                                        &self.wake_tx,
                                        &self.coord,
                                        conn_id,
                                        gen,
                                        Token::Binary { id: req_id },
                                        request,
                                    ));
                                }
                                FrameMsg::Response(_) => {
                                    conn.inflight += 1;
                                    local.push((
                                        Token::Binary { id: req_id },
                                        Response::err("server expects request frames"),
                                    ));
                                }
                            }
                        }
                        Err(e) => {
                            log::warn!("binary stream corrupt, closing connection: {e}");
                            fatal = true;
                            break;
                        }
                    }
                } else {
                    let Some(nl) = buf.iter().position(|&b| b == b'\n') else { break };
                    let line = &buf[..nl];
                    conn.rpos += nl + 1;
                    let parsed = std::str::from_utf8(line)
                        .map_err(|e| anyhow::anyhow!("request is not UTF-8: {e}"))
                        .and_then(|text| {
                            if text.trim().is_empty() {
                                Ok(None)
                            } else {
                                protocol::decode_request(text).map(Some)
                            }
                        });
                    match parsed {
                        Ok(None) => {} // blank line: ignored, no response
                        Ok(Some(request)) => {
                            let seq = conn.json_next_submit;
                            conn.json_next_submit += 1;
                            conn.inflight += 1;
                            jobs.push(make_job(
                                &self.comp_tx,
                                &self.wake_tx,
                                &self.coord,
                                conn_id,
                                gen,
                                Token::Json { seq },
                                request,
                            ));
                        }
                        Err(e) => {
                            let seq = conn.json_next_submit;
                            conn.json_next_submit += 1;
                            conn.inflight += 1;
                            local.push((Token::Json { seq }, Response::err(e)));
                        }
                    }
                }
            }
            // Compact the consumed prefix so the buffer stays bounded.
            if conn.rpos > 0 {
                conn.rbuf.drain(..conn.rpos);
                conn.rpos = 0;
            }
        }
        if frames_in > 0 {
            self.metrics().add("transport.frames_in", frames_in);
        }
        for (token, resp) in local {
            let payload = encode_payload(token, resp);
            self.apply_completion(Completion { conn: id, gen: self.gen_of(id), token, payload });
        }
        if fatal {
            self.close_conn(id);
        }
        jobs
    }

    fn gen_of(&self, id: usize) -> u64 {
        self.conns.get(id).and_then(Option::as_ref).map(|c| c.gen).unwrap_or(0)
    }

    fn submit_batch(&mut self, jobs: Vec<Job>) {
        let n = jobs.len() as u64;
        self.coord.submit_jobs(jobs);
        self.batch.batches += 1;
        self.batch.sum += n;
        self.batch.min = self.batch.min.min(n);
        self.batch.max = self.batch.max.max(n);
        let m = self.metrics();
        m.incr("transport.batches");
        m.gauge_set("transport.batch_size.min", self.batch.min as f64);
        m.gauge_set("transport.batch_size.max", self.batch.max as f64);
        m.gauge_set(
            "transport.batch_size.mean",
            self.batch.sum as f64 / self.batch.batches as f64,
        );
    }

    fn service_writable(&mut self, id: usize) {
        let Some(conn) = self.conns.get_mut(id).and_then(Option::as_mut) else { return };
        if conn.wqueue.is_empty() {
            return;
        }
        match conn.flush() {
            Ok(0) => {}
            Ok(n) => self.metrics().add("transport.bytes_out", n as u64),
            Err(e) => {
                log::debug!("connection write failed, closing: {e}");
                self.close_conn(id);
            }
        }
    }

    /// Close connections that hit EOF (or shutdown) once nothing is owed.
    fn reap_drained(&mut self) {
        for id in 0..self.conns.len() {
            let close = matches!(
                self.conns[id].as_ref(),
                Some(conn) if conn.closing && conn.drained()
            );
            if close {
                self.close_conn(id);
            }
        }
    }
}

/// Build a pool job whose reply callback encodes the response on the
/// worker thread, records per-op latency, and hands the finished bytes
/// back through the completion pipe + wake byte. A free function (not a
/// method) so the parse loop can call it while a connection is mutably
/// borrowed.
fn make_job(
    comp: &Sender<Completion>,
    wake: &Arc<UnixStream>,
    coord: &Arc<Coordinator>,
    conn: usize,
    gen: u64,
    token: Token,
    request: Request,
) -> Job {
    let comp = comp.clone();
    let wake = wake.clone();
    let coord = coord.clone();
    let op = request.op();
    let t0 = Instant::now();
    Job {
        request,
        reply: Reply::Callback(Box::new(move |resp| {
            coord.node().metrics().observe(op, t0.elapsed().as_secs_f64());
            let payload = encode_payload(token, resp);
            let _ = comp.send(Completion { conn, gen, token, payload });
            // WouldBlock means a wakeup is already pending: fine.
            let _ = (&*wake).write(&[1]);
        })),
    }
}

/// Encode on the worker thread. Binary responses use the splicing
/// encoder: a `sketch_fetch_bin` blob crosses from `codec` to the socket
/// as one owned buffer — never copied into a contiguous frame.
fn encode_payload(token: Token, resp: Response) -> Vec<Vec<u8>> {
    match token {
        Token::Binary { id } => frame::encode_response_frame_vectored(id, resp),
        Token::Json { .. } => vec![protocol::encode_line(&resp.to_json()).into_bytes()],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::service::CoordinatorConfig;
    use std::io::{BufRead, BufReader};

    fn start(workers: usize) -> (Arc<Coordinator>, EventServer) {
        let coord = Arc::new(
            Coordinator::new(CoordinatorConfig {
                k: 64,
                workers,
                ..CoordinatorConfig::default()
            })
            .unwrap(),
        );
        let server = EventServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        (coord, server)
    }

    fn send_frames(stream: &mut TcpStream, reqs: &[(u64, Request)]) {
        let mut buf = Vec::new();
        for (id, req) in reqs {
            frame::encode_request_frame(*id, req, &mut buf);
        }
        stream.write_all(&buf).unwrap();
    }

    fn read_frame(stream: &mut TcpStream, acc: &mut Vec<u8>) -> (u64, Response) {
        let mut chunk = [0u8; 4096];
        loop {
            match frame::decode_frame(acc).unwrap() {
                FrameStatus::Frame { consumed, id, msg } => {
                    acc.drain(..consumed);
                    let FrameMsg::Response(resp) = msg else {
                        panic!("server sent a request frame")
                    };
                    return (id, resp);
                }
                FrameStatus::Incomplete => {
                    let n = stream.read(&mut chunk).unwrap();
                    assert!(n > 0, "server closed mid-frame");
                    acc.extend_from_slice(&chunk[..n]);
                }
            }
        }
    }

    #[test]
    fn binary_ping_roundtrips() {
        let (coord, server) = start(2);
        let mut s = TcpStream::connect(server.addr).unwrap();
        send_frames(&mut s, &[(7, Request::Ping)]);
        let mut acc = Vec::new();
        let (id, resp) = read_frame(&mut s, &mut acc);
        assert_eq!(id, 7);
        assert_eq!(resp, Response::Pong);
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn pipelined_frames_answer_every_id_exactly_once() {
        let (coord, server) = start(4);
        let mut s = TcpStream::connect(server.addr).unwrap();
        let n = 64u64;
        let reqs: Vec<(u64, Request)> = (0..n).map(|i| (1000 + i, Request::Ping)).collect();
        send_frames(&mut s, &reqs);
        let mut acc = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..n {
            let (id, resp) = read_frame(&mut s, &mut acc);
            assert_eq!(resp, Response::Pong);
            assert!(seen.insert(id), "duplicate response id {id}");
            assert!((1000..1000 + n).contains(&id));
        }
        assert_eq!(seen.len(), n as usize);
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn existing_json_line_clients_work_unchanged() {
        let (coord, server) = start(2);
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        assert!(c.hello().is_ok());
        let resp = c.call(&Request::Ping).unwrap();
        assert_eq!(resp, Response::Pong);
        // Pipelined JSON keeps its in-order contract.
        let reqs: Vec<Request> = (0..16)
            .map(|i| Request::Push { stream: "s".into(), items: vec![(i, 1.0)] })
            .collect();
        let resps = c.call_pipelined(&reqs).unwrap();
        for (i, resp) in resps.iter().enumerate() {
            let Response::Ack { info } = resp else { panic!("expected ack, got {resp:?}") };
            assert!(
                info.contains(&format!("processed {}", i + 1)),
                "out of order at {i}: {info}"
            );
        }
        drop(c);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn one_connection_can_interleave_json_and_frames() {
        // workers=1 → completion order is submission order, so the JSON
        // line's response arrives before the frame's.
        let (coord, server) = start(1);
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        send_frames(&mut s, &[(42, Request::Hello)]);
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "json reply: {line}");
        let mut acc = r.buffer().to_vec(); // frame bytes the line read buffered
        let (id, resp) = read_frame(&mut s, &mut acc);
        assert_eq!(id, 42);
        assert!(matches!(resp, Response::Hello { .. }));
        drop(r);
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn transport_metrics_are_surfaced_through_the_metrics_op() {
        let (coord, server) = start(2);
        let mut s = TcpStream::connect(server.addr).unwrap();
        let reqs: Vec<(u64, Request)> = (0..8).map(|i| (i, Request::Ping)).collect();
        send_frames(&mut s, &reqs);
        let mut acc = Vec::new();
        for _ in 0..8 {
            read_frame(&mut s, &mut acc);
        }
        send_frames(&mut s, &[(99, Request::Metrics)]);
        let (_, resp) = read_frame(&mut s, &mut acc);
        let Response::MetricsDump { snapshot } = resp else { panic!("expected metrics") };
        let counter = |name: &str| {
            snapshot
                .get("counters")
                .and_then(|c| c.get(name))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        assert!(counter("transport.frames_in") >= 9.0, "{snapshot}");
        assert!(counter("transport.frames_out") >= 8.0, "{snapshot}");
        assert!(counter("transport.bytes_in") > 0.0, "{snapshot}");
        assert!(counter("transport.bytes_out") > 0.0, "{snapshot}");
        assert!(counter("transport.batches") >= 1.0, "{snapshot}");
        let gauge = |name: &str| {
            snapshot.get("gauges").and_then(|g| g.get(name)).and_then(|v| v.as_f64())
        };
        let min = gauge("transport.batch_size.min").expect("batch min gauge");
        let mean = gauge("transport.batch_size.mean").expect("batch mean gauge");
        let max = gauge("transport.batch_size.max").expect("batch max gauge");
        assert!(min >= 1.0 && min <= mean && mean <= max, "min={min} mean={mean} max={max}");
        // The 8-ping burst was written in one TCP segment: at least one
        // admission batch carried more than one request.
        assert!(max >= 2.0, "admission batching never batched: max={max}");
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn corrupt_frame_closes_the_connection() {
        let (coord, server) = start(1);
        let mut s = TcpStream::connect(server.addr).unwrap();
        let mut buf = Vec::new();
        frame::encode_request_frame(5, &Request::Ping, &mut buf);
        let last = buf.len() - 1;
        buf[last] ^= 0xFF; // break the checksum
        s.write_all(&buf).unwrap();
        let mut chunk = [0u8; 64];
        // The server must close without answering.
        s.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        assert_eq!(s.read(&mut chunk).unwrap(), 0, "expected EOF after corruption");
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn malformed_json_gets_an_error_line_and_the_stream_survives() {
        let (coord, server) = start(1);
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.write_all(b"this is not json\n{\"op\":\"ping\"}\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "first reply should be an error: {line}");
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "stream should survive the bad line: {line}");
        drop(r);
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn stop_returns_with_idle_connections_open() {
        let (coord, server) = start(1);
        let _idle = TcpStream::connect(server.addr).unwrap();
        let t0 = Instant::now();
        server.stop();
        assert!(t0.elapsed() < std::time::Duration::from_secs(5), "stop hung on idle conn");
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    #[test]
    fn full_flow_over_binary_frames() {
        let (coord, server) = start(2);
        let mut s = TcpStream::connect(server.addr).unwrap();
        let v = crate::sketch::SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
        send_frames(
            &mut s,
            &[(1, Request::Upsert { key: "doc".into(), vector: v.clone(), version: None })],
        );
        let mut acc = Vec::new();
        let (_, resp) = read_frame(&mut s, &mut acc);
        assert!(matches!(resp, Response::Ack { .. }), "upsert failed: {resp:?}");
        send_frames(&mut s, &[(2, Request::TopK { vector: v, limit: 1 })]);
        let (_, resp) = read_frame(&mut s, &mut acc);
        let Response::TopK { hits } = resp else { panic!("expected topk, got {resp:?}") };
        assert_eq!(hits[0].0, "doc");
        // Blob fetch rides the raw-bytes path end to end.
        send_frames(
            &mut s,
            &[(
                3,
                Request::SketchFetch {
                    name: "doc".into(),
                    source: crate::coordinator::protocol::SketchSource::Store,
                },
            )],
        );
        let (_, resp) = read_frame(&mut s, &mut acc);
        let Response::SketchBlob { data, .. } = resp else {
            panic!("expected blob, got {resp:?}")
        };
        let (key, _, _) = crate::sketch::codec::decode_sketch_hex(&data).unwrap();
        assert_eq!(key, "doc");
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }

    /// The binary blob ops over a live socket: a `sketch_fetch_bin`
    /// response leaves the server as a spliced multi-buffer frame, and
    /// what arrives decodes to the raw codec bytes; `store_put_bin`
    /// installs the blob back without any hex round trip.
    #[test]
    fn spliced_blob_frames_roundtrip_over_the_wire() {
        use crate::coordinator::protocol::SketchSource;
        use crate::sketch::codec;
        let (coord, server) = start(2);
        let mut s = TcpStream::connect(server.addr).unwrap();
        let v = crate::sketch::SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
        send_frames(
            &mut s,
            &[(1, Request::Upsert { key: "doc".into(), vector: v, version: None })],
        );
        let mut acc = Vec::new();
        let (_, resp) = read_frame(&mut s, &mut acc);
        assert!(matches!(resp, Response::Ack { .. }), "upsert failed: {resp:?}");
        send_frames(
            &mut s,
            &[(2, Request::SketchFetchBin { name: "doc".into(), source: SketchSource::Store })],
        );
        let (id, resp) = read_frame(&mut s, &mut acc);
        assert_eq!(id, 2);
        let Response::SketchBlobBin { name, data } = resp else {
            panic!("expected binary blob, got {resp:?}")
        };
        assert_eq!(name, "doc");
        let (key, version, sk) = codec::decode_sketch_bytes(&data).unwrap();
        assert_eq!((key.as_str(), version), ("doc", 1));
        // Round-trip: install the fetched registers under a new key,
        // binary both ways.
        send_frames(
            &mut s,
            &[(3, Request::StorePutBin { data: codec::encode_sketch_bytes("copy", 5, &sk) })],
        );
        let (_, resp) = read_frame(&mut s, &mut acc);
        let Response::Ack { info } = resp else { panic!("expected ack, got {resp:?}") };
        assert!(info.contains("installed 'copy' @v5"), "{info}");
        send_frames(
            &mut s,
            &[(4, Request::SketchFetchBin { name: "copy".into(), source: SketchSource::Store })],
        );
        let (_, resp) = read_frame(&mut s, &mut acc);
        let Response::SketchBlobBin { data, .. } = resp else {
            panic!("expected binary blob, got {resp:?}")
        };
        let (_, v2, sk2) = codec::decode_sketch_bytes(&data).unwrap();
        assert_eq!(v2, 5);
        assert_eq!(sk2, sk, "registers must survive the binary round trip bit-identically");
        drop(s);
        server.stop();
        Arc::try_unwrap(coord).ok().expect("coordinator still referenced").shutdown();
    }
}
