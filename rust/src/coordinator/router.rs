//! Routing policy: which execution path serves a sketch request.
//!
//! The paper's algorithm is a CPU win for sparse, high-dimensional vectors;
//! the AOT accelerator wins for dense low-dimensional batches (the
//! `ablation-accel` experiment quantifies the crossover). The router makes
//! that call per request from (a) the dense length limit the compiled
//! buckets accept and (b) a density heuristic for sparse inputs that
//! happen to be dense-representable.

use crate::sketch::{AlgorithmId, SparseVector};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// CPU FastGM (Ordered family): the paper's algorithm, one thread.
    CpuFastGm,
    /// Dense accelerator via the batcher (Direct family).
    Accelerator,
}

/// Execution plan for a `sketch` request: which engine-registry algorithm
/// runs it, and whether the FastGM shard team is engaged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchPlan {
    /// Run the named registry algorithm single-threaded.
    Engine(AlgorithmId),
    /// FastGM over the §2.3 shard team (bit-identical to plain FastGM).
    ShardedFastGm,
}

/// What a store-backed query op reads — the router's planning input
/// (normalized from the wire ops by the node's query engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryShape {
    /// Similarity ranking of a probe vector against the whole keyed store
    /// (currently `store_len` entries) — `topk`.
    Rank { store_len: usize },
    /// An explicit key set, union-merged into one sketch (§2.3) —
    /// `sample`/`partition` over `key`/`keys`.
    Keys,
    /// A live stream state's current sketch — `sample`/`partition` over
    /// `stream`.
    Stream,
}

/// Execution plan for a store-backed query — the single plan/execute seam
/// `topk`, `sample`, `partition` and future query ops flow through
/// (the node's query engine executes the plan, then applies the op's
/// estimator to what it read).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryPlan {
    /// Score every stored entry (exact; wins while the store is small —
    /// banding overhead plus imperfect recall buy nothing at that size).
    FullScan,
    /// Banded LSH candidate probe, then full-sketch re-rank (sub-linear).
    BandProbe,
    /// Union-merge the named keys' registers under the shard locks (no
    /// register clones on the hot path), then estimate on the merge.
    MergeKeys,
    /// Probe the versioned merge cache first; on a validated hit serve the
    /// cached union (bit-identical to the fresh merge by construction), on
    /// a miss fall back to [`QueryPlan::MergeKeys`] and fill the cache
    /// with the merged sketch tagged by its member version vector.
    CachedMerge,
    /// Read the named live stream state's current sketch.
    StreamSketch,
}

#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Largest dense length any compiled bucket accepts (0 = accel off).
    pub accel_max_len: usize,
    /// Minimum fill fraction for a sparse vector to be worth densifying.
    pub min_density: f64,
    /// Shard team size for the parallel CPU path (1 = never shard).
    pub shards: usize,
    /// Smallest n⁺ routed to the shard team: each shard re-pays FastGM's
    /// `O(k ln k)` FastSearch term, so small vectors stay single-threaded.
    pub shard_min_nplus: usize,
    /// Largest store size answered by a brute-force scan; bigger stores go
    /// through the banded LSH probe.
    pub topk_scan_max: usize,
    /// Probe-then-fill the versioned read-path cache for key-set merges
    /// (and, at the execution layer, top-k rankings). Off routes key-set
    /// queries straight to the uncached merge.
    pub cache: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            accel_max_len: 0,
            min_density: 0.25,
            shards: 1,
            shard_min_nplus: 4096,
            topk_scan_max: 64,
            cache: true,
        }
    }
}

pub struct Router {
    pub cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Router {
        Router { cfg }
    }

    /// Plan a `sketch` request for a registry algorithm. Only plain FastGM
    /// is upgraded to the shard team (sharding is its §2.3 property; an
    /// explicitly requested `sharded` algo is already parallel, and every
    /// other algorithm runs as asked).
    pub fn plan_sketch(&self, algo: AlgorithmId, n_plus: usize) -> SketchPlan {
        if algo == AlgorithmId::FastGm
            && self.cfg.shards > 1
            && n_plus >= self.cfg.shard_min_nplus
        {
            SketchPlan::ShardedFastGm
        } else {
            SketchPlan::Engine(algo)
        }
    }

    /// Plan a store-backed query. Ranking queries pick scan-vs-probe by
    /// store size (the old `topk` routing, unchanged — the execution layer
    /// wraps either plan with the generation-tagged top-k cache when
    /// `cache` is on); key-set queries route through the versioned merge
    /// cache (`CachedMerge` probe-then-fill) unless caching is off; stream
    /// queries read live state and are never cached (their sketch mutates
    /// without a version to validate against — TTL caching is recorded
    /// headroom, not policy).
    pub fn plan_query(&self, shape: QueryShape) -> QueryPlan {
        match shape {
            QueryShape::Rank { store_len } => {
                if store_len <= self.cfg.topk_scan_max {
                    QueryPlan::FullScan
                } else {
                    QueryPlan::BandProbe
                }
            }
            QueryShape::Keys => {
                if self.cfg.cache {
                    QueryPlan::CachedMerge
                } else {
                    QueryPlan::MergeKeys
                }
            }
            QueryShape::Stream => QueryPlan::StreamSketch,
        }
    }

    /// Plan a keyed-store `topk` request from the current store size.
    pub fn plan_topk(&self, store_len: usize) -> QueryPlan {
        self.plan_query(QueryShape::Rank { store_len })
    }

    /// Route an explicitly dense request (weights indexed 0..len).
    pub fn route_dense(&self, len: usize) -> Path {
        if self.cfg.accel_max_len >= len && len > 0 {
            Path::Accelerator
        } else {
            Path::CpuFastGm
        }
    }

    /// Route a sparse vector: densify only when the id space is small
    /// enough for a bucket AND the vector is dense enough that padding
    /// waste stays bounded.
    pub fn route_sparse(&self, v: &SparseVector) -> Path {
        if self.cfg.accel_max_len == 0 {
            return Path::CpuFastGm;
        }
        let Some(max_id) = v.positive().map(|(id, _)| id).max() else {
            return Path::CpuFastGm;
        };
        let span = max_id as usize + 1;
        if span > self.cfg.accel_max_len {
            return Path::CpuFastGm;
        }
        let density = v.n_plus() as f64 / span as f64;
        if density >= self.cfg.min_density {
            Path::Accelerator
        } else {
            Path::CpuFastGm
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_routes_by_bucket_limit() {
        let r = Router::new(RouterConfig {
            accel_max_len: 1024,
            min_density: 0.25,
            ..RouterConfig::default()
        });
        assert_eq!(r.route_dense(512), Path::Accelerator);
        assert_eq!(r.route_dense(1024), Path::Accelerator);
        assert_eq!(r.route_dense(4096), Path::CpuFastGm);
        assert_eq!(r.route_dense(0), Path::CpuFastGm);
    }

    #[test]
    fn accel_off_routes_everything_to_cpu() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.route_dense(16), Path::CpuFastGm);
        let v = SparseVector::new(vec![1, 2], vec![1.0, 1.0]);
        assert_eq!(r.route_sparse(&v), Path::CpuFastGm);
    }

    #[test]
    fn sketch_plans_by_shard_threshold() {
        let r = Router::new(RouterConfig {
            shards: 4,
            shard_min_nplus: 1000,
            ..RouterConfig::default()
        });
        let single = SketchPlan::Engine(AlgorithmId::FastGm);
        assert_eq!(r.plan_sketch(AlgorithmId::FastGm, 10), single);
        assert_eq!(r.plan_sketch(AlgorithmId::FastGm, 999), single);
        assert_eq!(r.plan_sketch(AlgorithmId::FastGm, 1000), SketchPlan::ShardedFastGm);
        assert_eq!(
            r.plan_sketch(AlgorithmId::FastGm, 1_000_000),
            SketchPlan::ShardedFastGm
        );
        // shards == 1 disables the parallel path regardless of size.
        let one = Router::new(RouterConfig {
            shards: 1,
            shard_min_nplus: 0,
            ..RouterConfig::default()
        });
        assert_eq!(one.plan_sketch(AlgorithmId::FastGm, 1_000_000), single);
    }

    #[test]
    fn plan_upgrades_only_fastgm_to_the_shard_team() {
        let r = Router::new(RouterConfig {
            shards: 4,
            shard_min_nplus: 100,
            ..RouterConfig::default()
        });
        assert_eq!(
            r.plan_sketch(AlgorithmId::FastGm, 1000),
            SketchPlan::ShardedFastGm
        );
        assert_eq!(
            r.plan_sketch(AlgorithmId::FastGm, 99),
            SketchPlan::Engine(AlgorithmId::FastGm)
        );
        // Every other algorithm runs exactly as requested, any size.
        for algo in AlgorithmId::ALL {
            if algo == AlgorithmId::FastGm {
                continue;
            }
            assert_eq!(r.plan_sketch(algo, 1_000_000), SketchPlan::Engine(algo));
        }
    }

    #[test]
    fn topk_plans_by_store_size() {
        let r = Router::new(RouterConfig { topk_scan_max: 64, ..RouterConfig::default() });
        assert_eq!(r.plan_topk(0), QueryPlan::FullScan);
        assert_eq!(r.plan_topk(64), QueryPlan::FullScan);
        assert_eq!(r.plan_topk(65), QueryPlan::BandProbe);
        assert_eq!(r.plan_topk(1_000_000), QueryPlan::BandProbe);
        // scan_max = 0 probes everything non-empty.
        let always = Router::new(RouterConfig { topk_scan_max: 0, ..RouterConfig::default() });
        assert_eq!(always.plan_topk(1), QueryPlan::BandProbe);
        assert_eq!(always.plan_topk(0), QueryPlan::FullScan);
    }

    #[test]
    fn every_query_shape_plans_through_the_one_seam() {
        let r = Router::new(RouterConfig { topk_scan_max: 2, ..RouterConfig::default() });
        assert_eq!(r.plan_query(QueryShape::Rank { store_len: 1 }), QueryPlan::FullScan);
        assert_eq!(r.plan_query(QueryShape::Rank { store_len: 3 }), QueryPlan::BandProbe);
        // Cache on (the default): key sets probe-then-fill the merge cache.
        assert_eq!(r.plan_query(QueryShape::Keys), QueryPlan::CachedMerge);
        assert_eq!(r.plan_query(QueryShape::Stream), QueryPlan::StreamSketch);
        // Cache off: key sets route straight to the uncached merge, and
        // nothing else moves.
        let off = Router::new(RouterConfig { cache: false, ..RouterConfig::default() });
        assert_eq!(off.plan_query(QueryShape::Keys), QueryPlan::MergeKeys);
        assert_eq!(off.plan_query(QueryShape::Stream), QueryPlan::StreamSketch);
    }

    #[test]
    fn sparse_density_heuristic() {
        let r = Router::new(RouterConfig {
            accel_max_len: 1024,
            min_density: 0.25,
            ..RouterConfig::default()
        });
        // Dense-ish small-span vector → accelerator.
        let dense = SparseVector::new((0..512u64).collect(), vec![1.0; 512]);
        assert_eq!(r.route_sparse(&dense), Path::Accelerator);
        // Sparse vector in a small span → CPU.
        let sparse = SparseVector::new(vec![5, 900], vec![1.0, 1.0]);
        assert_eq!(r.route_sparse(&sparse), Path::CpuFastGm);
        // Huge id (hashed token) → CPU regardless of count.
        let hashed = SparseVector::new(vec![u64::MAX - 3], vec![1.0]);
        assert_eq!(r.route_sparse(&hashed), Path::CpuFastGm);
        // Empty → CPU (no-op).
        assert_eq!(r.route_sparse(&SparseVector::default()), Path::CpuFastGm);
    }
}
