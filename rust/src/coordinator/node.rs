//! The transport-agnostic node core: everything that *executes* requests.
//!
//! [`Node::execute`] is the single typed entry point — `Request` in,
//! `Response` out, no socket, no worker pool, no transport anywhere in the
//! signature. The TCP server, the CLI, the cluster layer and the tests are
//! all thin callers:
//!
//! ```text
//!   TCP server ──┐
//!   CLI ─────────┼──► Coordinator (worker pool) ──► Node::execute
//!   tests ───────┘                                      ▲
//!   library embedders ──────────────────────────────────┘
//! ```
//!
//! A `Node` owns the engine registry, the named sketch/stream registry, the
//! keyed similarity store, the LSH index, the dense batcher and the
//! metrics — the full request-execution state of one site in the paper's
//! §2.3 many-sites deployment. What it deliberately does NOT own: threads
//! (the [`super::service::Coordinator`] wraps it in a worker pool) and
//! transports (the [`super::server::Server`] speaks TCP on top of the
//! coordinator; [`super::cluster`] fans out across many nodes).
//!
//! Family discipline (README.md §RNG-families): the `sketch` op always
//! produces **Ordered**-family FastGM sketches; `sketch_dense` always
//! produces **Direct**-family sketches (accelerator or CPU P-MinHash
//! fallback — identical semantics). Estimators reject cross-family pairs,
//! so a mis-routed comparison fails loudly instead of silently biasing.

use super::batcher::{BatcherConfig, DenseBatcher};
use super::cache::{self, ByteLruCache, Digest};
use super::merger::merge_tree;
use super::metrics::Metrics;
use super::protocol::{HelloInfo, QueryTarget, Request, Response, SketchSource, PROTOCOL_VERSION};
use super::registry::Registry;
use super::router::{QueryPlan, QueryShape, Router, RouterConfig, SketchPlan};
use super::store::SketchStore;
use crate::estimate::cardinality::{estimate_cardinality, estimate_weighted_jaccard};
use crate::estimate::jaccard::estimate_jp;
use crate::estimate::sample;
use crate::lsh::{LshIndex, LshParams};
use crate::sketch::engine::{self, EngineParams};
use crate::sketch::{codec, AlgorithmId, GumbelMaxSketch, SketchScratch, Sketcher, SparseVector};
use crate::util::config::Config;
use crate::util::hash::token_id;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub k: usize,
    pub seed: u64,
    pub workers: usize,
    pub queue_capacity: usize,
    pub shed: bool,
    /// Artifact directory; None (or missing manifest) disables the
    /// accelerator — everything runs on CPU with identical semantics.
    pub artifacts_dir: Option<String>,
    pub batch_max: usize,
    pub batch_deadline: Duration,
    pub lsh_threshold: f64,
    /// Shard team size for large sparse `sketch` requests (§2.3 parallel
    /// shard-merge; 1 disables). The sharded result is bit-identical to
    /// single-threaded FastGM.
    pub shards: usize,
    /// Smallest n⁺ routed to the shard team.
    pub shard_min_nplus: usize,
    /// Default engine-registry algorithm for `sketch` requests that carry
    /// no `algo` field (config key `sketch.algo`).
    pub algo: String,
    /// Lock shards of the keyed sketch store (config key `store.shards`).
    pub store_shards: usize,
    /// Largest store size a `topk` answers by brute-force scan instead of
    /// the LSH band probe (config key `store.topk_scan_max`).
    pub topk_scan_max: usize,
    /// This node's identity in a cluster (config key `node.id`), reported
    /// by the `hello` handshake and used by the rendezvous partitioner —
    /// it must be unique and stable across restarts of the same site.
    pub node_id: String,
    /// Read-path cache budget in bytes (config key `cache.max_bytes`, CLI
    /// `serve --cache-bytes`), split evenly between the merged-union cache
    /// and the top-k result cache. 0 disables caching entirely.
    pub cache_max_bytes: usize,
    /// Master switch for the read-path cache (config key `cache.enabled`);
    /// off means every key-set query re-runs the §2.3 merge and every
    /// `topk` re-ranks — PR 8 behavior exactly.
    pub cache_enabled: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            k: 256,
            seed: 42,
            workers: 4,
            queue_capacity: 1024,
            shed: false,
            artifacts_dir: None,
            batch_max: 8,
            batch_deadline: Duration::from_millis(2),
            lsh_threshold: 0.5,
            shards: 4,
            shard_min_nplus: 4096,
            algo: "fastgm".to_string(),
            store_shards: 8,
            topk_scan_max: 64,
            node_id: "node-0".to_string(),
            cache_max_bytes: 8 << 20,
            cache_enabled: true,
        }
    }
}

impl CoordinatorConfig {
    /// Read from a parsed TOML-subset [`Config`] (the launcher path).
    pub fn from_config(cfg: &Config) -> CoordinatorConfig {
        let d = CoordinatorConfig::default();
        CoordinatorConfig {
            k: cfg.usize("sketch.k", d.k),
            seed: cfg.u64("sketch.seed", d.seed),
            workers: cfg.usize("server.workers", d.workers),
            queue_capacity: cfg.usize("server.queue_capacity", d.queue_capacity),
            shed: cfg.bool("server.shed", d.shed),
            artifacts_dir: {
                let dir = cfg.str("accel.artifacts_dir", "artifacts");
                if dir.is_empty() || dir == "off" {
                    None
                } else {
                    Some(dir)
                }
            },
            batch_max: cfg.usize("accel.max_batch", d.batch_max),
            batch_deadline: Duration::from_micros(
                (cfg.f64("accel.deadline_ms", 2.0) * 1000.0) as u64,
            ),
            lsh_threshold: cfg.f64("lsh.threshold", d.lsh_threshold),
            shards: cfg.usize("sketch.shards", d.shards),
            shard_min_nplus: cfg.usize("sketch.shard_min_nplus", d.shard_min_nplus),
            algo: cfg.str("sketch.algo", &d.algo),
            store_shards: cfg.usize("store.shards", d.store_shards),
            topk_scan_max: cfg.usize("store.topk_scan_max", d.topk_scan_max),
            node_id: cfg.str("node.id", &d.node_id),
            cache_max_bytes: cfg.usize("cache.max_bytes", d.cache_max_bytes),
            cache_enabled: cfg.bool("cache.enabled", d.cache_enabled),
        }
    }
}

/// A cached merged union: the §2.3 merge result plus its exact-invalidation
/// tag — the member `(key, version)` vector `merge_keys` reported and the
/// store's version-drop generation at merge time. Valid iff
/// [`SketchStore::members_match`] re-proves both against the live store.
struct MergeEntry {
    sketch: GumbelMaxSketch,
    members: Vec<(String, u64)>,
    delete_gen: u64,
}

/// A cached top-k ranking, tagged with the per-shard write generations the
/// store held *before* the ranking ran: a ranking read every entry, so any
/// write anywhere is grounds for invalidation.
struct TopKEntry {
    hits: Vec<(String, f64)>,
    gens: Vec<u64>,
}

/// A cached "this key does not exist" answer (ROADMAP item 5's negative
/// cache), tagged with the same per-shard write generations the top-k
/// entries use: absence can only stop being true through a write, so any
/// store write invalidating the tag is exact, never conservative-stale.
struct NegEntry {
    gens: Vec<u64>,
}

pub struct Node {
    cfg: CoordinatorConfig,
    registry: Registry,
    metrics: Metrics,
    router: Router,
    batcher: DenseBatcher,
    lsh: RwLock<LshIndex>,
    lsh_names: RwLock<HashMap<u64, String>>,
    /// Keyed similarity-serving store (upsert/delete/topk/snapshot ops).
    store: SketchStore,
    /// Merged-union read cache (the `sample`/`partition` key-set target):
    /// normalized key-set digest → [`MergeEntry`]. Hits are re-proved
    /// against the live store's versions before being served, so a cached
    /// union is bit-identical to a fresh merge by construction.
    merge_cache: ByteLruCache<Arc<MergeEntry>>,
    /// Top-k result cache: query-register digest → [`TopKEntry`].
    topk_cache: ByteLruCache<Arc<TopKEntry>>,
    /// Negative cache: key digest → [`NegEntry`] proving the key was
    /// absent at some generation snapshot. Consulted before the store on
    /// `sketch_fetch` store misses and key-set merges, so a gather loop
    /// hammering a nonexistent key stops re-probing every shard.
    neg_cache: ByteLruCache<Arc<NegEntry>>,
    /// `cfg.cache_enabled && cfg.cache_max_bytes > 0`, resolved once.
    cache_on: bool,
    accel_on: bool,
    /// Resolved `cfg.algo` (validated at construction time).
    default_algo: AlgorithmId,
    /// Engine-registry construction parameters shared by all algorithms.
    engine_params: EngineParams,
    /// Registry sketchers, shared across callers (stateless; all
    /// per-request state lives in the caller's scratch). The ONLY
    /// construction path for sketchers — pre-seeded with the hot entries,
    /// lazily extended per requested `algo` — so (k, seed, shards) can
    /// never diverge between the default path and per-request overrides.
    engines: RwLock<HashMap<AlgorithmId, Arc<dyn Sketcher>>>,
    /// State epoch: bumped on every successful snapshot `restore`, so a
    /// cluster client can tell "same node, same state" from "same node,
    /// state replaced" across a warm restart. Reported by `hello`.
    epoch: AtomicU64,
}

impl Node {
    pub fn new(cfg: CoordinatorConfig) -> anyhow::Result<Node> {
        // Bucket metadata comes from the manifest WITHOUT touching PJRT
        // (the xla wrapper types are !Send); the batcher thread owns the
        // actual runtime.
        let (accel_dir, accel_max_len) = match &cfg.artifacts_dir {
            // Without the `accel` feature a manifest may parse but can never
            // be loaded: report the accelerator as off (accel_enabled(),
            // metrics, router max_len) instead of advertising a path that
            // cannot exist. Dense requests still flow through the batcher's
            // CPU fallback.
            Some(dir) if !cfg!(feature = "accel") => {
                log::warn!("accel.artifacts_dir '{dir}' ignored: built without the `accel` feature");
                (None, 0)
            }
            Some(dir) => match crate::runtime::read_manifest(dir) {
                Ok(specs) => {
                    let max_len = specs
                        .iter()
                        .filter(|s| {
                            s.name.starts_with("sketch_b")
                                && s.outputs.first().map(|o| o.shape[1]) == Some(cfg.k)
                        })
                        .map(|s| s.inputs[1].shape[1])
                        .max()
                        .unwrap_or(0);
                    (Some(dir.clone()), max_len)
                }
                Err(e) => {
                    log::warn!("accelerator disabled: {e}");
                    (None, 0)
                }
            },
            None => (None, 0),
        };
        // A misconfigured default algorithm fails loudly at startup instead
        // of per request (checked before any thread is spawned).
        let default_algo = AlgorithmId::from_name(&cfg.algo)?;
        let accel_on = accel_dir.is_some();
        let batcher = DenseBatcher::new(
            BatcherConfig {
                max_batch: cfg.batch_max,
                deadline: cfg.batch_deadline,
                k: cfg.k,
                seed: cfg.seed,
            },
            accel_dir,
        );
        let engine_params =
            EngineParams::new(cfg.k, cfg.seed).with_shards(cfg.shards.max(1));
        // Pre-seed the hot registry entries (default algo + both routed
        // FastGM paths) so steady-state requests never take the write lock.
        let mut engines: HashMap<AlgorithmId, Arc<dyn Sketcher>> = HashMap::new();
        for id in [default_algo, AlgorithmId::FastGm, AlgorithmId::Sharded] {
            engines
                .entry(id)
                .or_insert_with(|| Arc::from(engine::build(id, engine_params)));
        }
        let lsh_params = LshParams::for_threshold(cfg.k, cfg.lsh_threshold);
        let cache_on = cfg.cache_enabled && cfg.cache_max_bytes > 0;
        // Half the byte budget each: merged unions are big (k × 16-byte
        // registers) and rankings are small (limit × name), so the top-k
        // half effectively never evicts while the merge half does the real
        // LRU work.
        let merge_budget = cfg.cache_max_bytes / 2;
        // Negative entries are tiny (a key plus one u64 per store shard),
        // so a sliver of the ranking half bounds them comfortably.
        let neg_budget = (cfg.cache_max_bytes - merge_budget) / 8;
        Ok(Node {
            router: Router::new(RouterConfig {
                accel_max_len,
                min_density: 0.25,
                shards: cfg.shards.max(1),
                shard_min_nplus: cfg.shard_min_nplus,
                topk_scan_max: cfg.topk_scan_max,
                cache: cache_on,
            }),
            registry: Registry::new(),
            metrics: Metrics::new(),
            batcher,
            lsh: RwLock::new(LshIndex::new(lsh_params)),
            lsh_names: RwLock::new(HashMap::new()),
            store: SketchStore::new(lsh_params, cfg.store_shards.max(1)),
            merge_cache: ByteLruCache::new(merge_budget, 8),
            topk_cache: ByteLruCache::new(cfg.cache_max_bytes - merge_budget - neg_budget, 8),
            neg_cache: ByteLruCache::new(neg_budget, 8),
            cache_on,
            accel_on,
            default_algo,
            engine_params,
            engines: RwLock::new(engines),
            epoch: AtomicU64::new(0),
            cfg,
        })
    }

    /// Execute one request against this node's state. This is the typed,
    /// transport-agnostic API everything else is a wrapper around: errors
    /// become [`Response::Error`], never panics. `scratch` is the caller's
    /// reusable sketch arena (the worker pool passes its per-worker one);
    /// reuse is bit-invisible, so any scratch — however dirty — is fine.
    pub fn execute(&self, req: Request, scratch: &mut SketchScratch) -> Response {
        match self.execute_inner(req, scratch) {
            Ok(resp) => resp,
            Err(e) => {
                self.metrics.incr("errors");
                Response::err(e)
            }
        }
    }

    /// [`Node::execute`] with a throwaway scratch — the convenience path
    /// for embedders and tests that don't manage worker state.
    pub fn execute_alloc(&self, req: Request) -> Response {
        self.execute(req, &mut SketchScratch::new())
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn id(&self) -> &str {
        &self.cfg.node_id
    }

    /// Snapshot-restore count (see the `epoch` field).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    pub fn accel_enabled(&self) -> bool {
        self.accel_on
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Value {
        self.metrics.snapshot()
    }

    /// The `hello` handshake payload (also reachable without the wire).
    pub fn hello(&self) -> HelloInfo {
        HelloInfo {
            protocol: PROTOCOL_VERSION,
            node: self.cfg.node_id.clone(),
            epoch: self.epoch(),
            k: self.cfg.k,
            seed: self.cfg.seed,
            algo: self.default_algo.name().to_string(),
            algos: AlgorithmId::ALL.iter().map(|a| a.name().to_string()).collect(),
        }
    }

    /// Drain the batcher thread. Called by the owning coordinator once the
    /// worker pool is down (or directly by pool-less embedders).
    pub fn shutdown(self) {
        self.batcher.shutdown();
    }

    /// The shared registry sketcher for `id`, built on first use.
    fn engine(&self, id: AlgorithmId) -> Arc<dyn Sketcher> {
        if let Some(e) = self.engines.read().unwrap().get(&id) {
            return e.clone();
        }
        let built: Arc<dyn Sketcher> = Arc::from(engine::build(id, self.engine_params));
        self.engines.write().unwrap().entry(id).or_insert(built).clone()
    }

    /// Sparse sketch through the engine registry. `algo` is the request's
    /// override (validated here — unknown names become error responses);
    /// `None` means the configured default. Plain FastGM may be upgraded to
    /// the §2.3 shard team by the router — identical output either way (the
    /// router only decides parallelism, never the algorithm). The caller's
    /// scratch is reused across requests; `sketch_into` is bit-identical to
    /// a fresh sketch, so reuse is invisible to callers.
    fn sketch_sparse(
        &self,
        v: &SparseVector,
        algo: Option<&str>,
        scratch: &mut SketchScratch,
    ) -> anyhow::Result<GumbelMaxSketch> {
        let id = match algo {
            Some(name) => AlgorithmId::from_name(name)?,
            None => self.default_algo,
        };
        if scratch.begin_use() {
            self.metrics.incr("scratch.reuse");
        } else {
            self.metrics.incr("scratch.alloc");
        }
        let mut out = GumbelMaxSketch::empty(id.family(), self.cfg.seed, self.cfg.k);
        match self.router.plan_sketch(id, v.n_plus()) {
            SketchPlan::ShardedFastGm => {
                self.metrics.incr("path.sketch.sharded");
                self.engine(AlgorithmId::Sharded).sketch_into(v, scratch, &mut out);
            }
            SketchPlan::Engine(AlgorithmId::FastGm) => {
                self.metrics.incr("path.sketch.single");
                self.engine(AlgorithmId::FastGm).sketch_into(v, scratch, &mut out);
            }
            SketchPlan::Engine(other) => {
                self.metrics.incr(&format!("path.sketch.engine.{}", other.name()));
                self.engine(other).sketch_into(v, scratch, &mut out);
            }
        }
        Ok(out)
    }

    /// LSH banding and the keyed store score candidates with
    /// `estimate_jp`, which is only defined for EXP-register families —
    /// with a `sketch.algo` default of icws / bagminhash / minhash, the
    /// similarity-serving ops (`lsh_insert`, `lsh_query`, `upsert`, `topk`,
    /// `restore`) refuse up front with one clear message instead of
    /// erroring candidate-by-candidate mid-query.
    fn ensure_lsh_capable(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.default_algo.family().has_exponential_registers(),
            "similarity serving (LSH / store top-k) requires an EXP-register default algo \
             (ordered/direct families); configured sketch.algo '{}' is family '{}'",
            self.default_algo.name(),
            self.default_algo.family().name(),
        );
        Ok(())
    }

    fn neg_digest(key: &str) -> u64 {
        let mut d = Digest::new();
        d.str(key);
        d.finish()
    }

    /// True when a still-valid cached miss proves `key` is absent — the
    /// caller may fail without re-probing the store. A hit can never mask
    /// a racing insert: the entry's generation tag was snapshotted before
    /// the probe that proved absence, and any write bumps its shard's
    /// generation inside the store's critical section, so the entry only
    /// ever validates stale.
    fn cached_missing(&self, key: &str) -> bool {
        if !self.cache_on {
            return false;
        }
        let hit = self
            .neg_cache
            .get_validated(Self::neg_digest(key), |e| self.store.generations() == e.gens)
            .is_some();
        if hit {
            self.metrics.incr("cache.neg_hit");
        }
        hit
    }

    /// Remember that the store just proved `key` absent. `gens` must have
    /// been snapshotted BEFORE the probe (the same discipline as the
    /// top-k tags). Counted as `cache.neg_miss`: a miss that had to touch
    /// the store and is now cached.
    fn remember_missing(&self, key: &str, gens: Vec<u64>) {
        if !self.cache_on || gens.is_empty() {
            return;
        }
        self.metrics.incr("cache.neg_miss");
        let cost = 32 + key.len() + gens.len() * 8;
        self.neg_cache.insert(Self::neg_digest(key), Arc::new(NegEntry { gens }), cost);
    }

    /// Resolve a `sketch_fetch` source to `(version, sketch)` — the shared
    /// core of the hex and binary blob ops, so both transports serve the
    /// same bytes, the same errors and the same metrics. Store blobs carry
    /// the key's write version (the LWW tiebreaker replicas converge by);
    /// registry and stream sketches have no write history — their blobs
    /// say 0. Store misses consult the negative cache before touching the
    /// shards, and fresh misses are remembered.
    fn fetch_sketch(
        &self,
        name: &str,
        source: SketchSource,
    ) -> anyhow::Result<(u64, GumbelMaxSketch)> {
        let found = match source {
            SketchSource::Store => {
                if self.cached_missing(name) {
                    anyhow::bail!("no {} sketch named '{name}'", source.name());
                }
                let gens =
                    if self.cache_on { self.store.generations() } else { Vec::new() };
                let got = self.store.get_versioned(name);
                if got.is_none() {
                    self.remember_missing(name, gens);
                }
                got
            }
            SketchSource::Registry => self.registry.get_sketch(name).map(|s| (0, s)),
            SketchSource::Stream => self.registry.stream_sketch(name).map(|s| (0, s)),
        };
        let (version, sk) = found
            .ok_or_else(|| anyhow::anyhow!("no {} sketch named '{name}'", source.name()))?;
        self.metrics.incr("store.fetch");
        Ok((version, sk))
    }

    /// Install one decoded codec blob under LWW — the shared core of
    /// `store_put` and `store_put_bin` (identical config gates, acks and
    /// errors on both transports).
    fn store_put_sketch(
        &self,
        key: String,
        version: u64,
        sk: GumbelMaxSketch,
    ) -> anyhow::Result<Response> {
        anyhow::ensure!(
            key.len() <= codec::MAX_KEY_LEN,
            "store keys are limited to {} bytes (got {})",
            codec::MAX_KEY_LEN,
            key.len(),
        );
        // Same gate as `restore`: only blobs at the serving config can
        // enter the store (a repair peer at another (family, seed, k)
        // must fail loudly, not index garbage).
        anyhow::ensure!(
            sk.family == self.default_algo.family()
                && sk.seed == self.cfg.seed
                && sk.k() == self.cfg.k,
            "store_put blob '{key}' (family '{}', seed {}, k {}) does not match \
             the serving config (family '{}', seed {}, k {})",
            sk.family.name(),
            sk.seed,
            sk.k(),
            self.default_algo.family().name(),
            self.cfg.seed,
            self.cfg.k,
        );
        self.metrics.incr("store.put");
        Ok(match self.store.put_versioned(&key, version, sk) {
            Some(v) => Response::Ack { info: format!("installed '{key}' @v{v}") },
            None => Response::Ack {
                info: format!(
                    "kept '{key}' @v{} (stale blob v{version})",
                    self.store.version_of(&key).unwrap_or(0),
                ),
            },
        })
    }

    /// Absorb one decoded peer stream sketch (§2.3 union merge) — shared
    /// by `stream_merge` and `stream_merge_bin`.
    fn stream_merge_sketch(
        &self,
        stream: String,
        sk: &GumbelMaxSketch,
    ) -> anyhow::Result<Response> {
        self.registry.stream_merge(&stream, self.cfg.k, self.cfg.seed, sk)?;
        self.metrics.incr("stream.merge");
        Ok(Response::Ack { info: format!("merged into stream '{stream}'") })
    }

    /// Resolve a query target to the sketch its estimator runs over — the
    /// execute half of the plan/execute seam (every store-backed read is
    /// routed by [`Router::plan_query`]; the cached-merge access path the
    /// seam was built for lives behind [`QueryPlan::CachedMerge`]). Key
    /// sets union-merge under the store's shard locks with no register
    /// clones — or are served from the versioned merge cache when the
    /// store can prove every member `(key, version)` is unchanged; stream
    /// targets always read the live stream state (never cached — their
    /// state has no version to validate against).
    fn read_query_target(&self, target: &QueryTarget) -> anyhow::Result<GumbelMaxSketch> {
        let shape = match target {
            QueryTarget::Keys(_) => QueryShape::Keys,
            QueryTarget::Stream(_) => QueryShape::Stream,
        };
        match (self.router.plan_query(shape), target) {
            (QueryPlan::CachedMerge, QueryTarget::Keys(keys)) => {
                // Normalize first: the §2.3 union merge is idempotent and
                // order-free, so the sorted deduped member list is both the
                // canonical cache identity and a bit-identical merge input.
                let mut members: Vec<String> = keys.clone();
                members.sort_unstable();
                members.dedup();
                let mut d = Digest::new();
                for key in &members {
                    d.str(key);
                }
                let digest = d.finish();
                if let Some(hit) = self.merge_cache.get_validated(digest, |e| {
                    self.store.members_match(&e.members, e.delete_gen)
                }) {
                    self.metrics.incr("path.query.merge_cached");
                    return Ok(hit.sketch.clone());
                }
                // A member key the store has already proved absent fails
                // here without re-probing the shards — the same error the
                // merge below would produce.
                for key in &members {
                    if self.cached_missing(key) {
                        anyhow::bail!("no store entry '{key}'");
                    }
                }
                self.metrics.incr("path.query.merge_keys");
                // Tag snapshot happens BEFORE the merge: a write racing the
                // merge bumps its counter first (inside the store's
                // critical section), so the entry can only validate stale —
                // it can never serve pre-write registers as post-write
                // state.
                let delete_gen = self.store.delete_generation();
                let gens = self.store.generations();
                let (sk, versions) = match self.store.merge_keys(&members) {
                    Ok(got) => got,
                    Err(e) => {
                        // Remember which member the store proved absent so
                        // the next repeat of this still-failing query is a
                        // negative-cache hit.
                        if let Some(missing) =
                            members.iter().find(|key| self.store.version_of(key).is_none())
                        {
                            self.remember_missing(missing, gens);
                        }
                        return Err(e);
                    }
                };
                let members: Vec<(String, u64)> =
                    members.into_iter().zip(versions).collect();
                let cost = sk.k() * 16
                    + members.iter().map(|(key, _)| key.len() + 24).sum::<usize>()
                    + 64;
                self.merge_cache.insert(
                    digest,
                    Arc::new(MergeEntry { sketch: sk.clone(), members, delete_gen }),
                    cost,
                );
                Ok(sk)
            }
            (QueryPlan::MergeKeys, QueryTarget::Keys(keys)) => {
                self.metrics.incr("path.query.merge_keys");
                let (sk, _versions) = self.store.merge_keys(keys)?;
                Ok(sk)
            }
            (QueryPlan::StreamSketch, QueryTarget::Stream(name)) => {
                self.metrics.incr("path.query.stream");
                self.registry
                    .stream_sketch(name)
                    .ok_or_else(|| anyhow::anyhow!("no stream named '{name}'"))
            }
            (plan, _) => anyhow::bail!("planner returned {plan:?} for {target:?}"),
        }
    }

    /// Refresh the store gauges. Sampled only when a `metrics` request is
    /// served (same policy as `queue_depth`): refreshing after every
    /// upsert/delete would re-scan every shard lock per mutation, purely
    /// to update a gauge only the metrics snapshot reads.
    fn observe_store(&self) {
        self.metrics.gauge_set("store.size", self.store.len() as f64);
        self.metrics.gauge_set("store.lsh_size", self.store.lsh_len() as f64);
        let cs = cache::combine(self.merge_cache.stats(), self.topk_cache.stats());
        self.metrics.gauge_set("cache.hit", cs.hits as f64);
        self.metrics.gauge_set("cache.miss", cs.misses as f64);
        self.metrics.gauge_set("cache.evict", cs.evictions as f64);
        self.metrics.gauge_set("cache.stale_drop", cs.stale_drops as f64);
        self.metrics.gauge_set("cache.bytes", cs.bytes as f64);
    }

    /// [`SketchStore::stats`] plus the combined `cache` object — the one
    /// payload both the `store_stats` and `metrics` ops embed, on both
    /// transports (the wire carries stats as opaque JSON, so this needed
    /// no protocol change).
    fn store_stats_with_cache(&self) -> crate::util::json::Value {
        let mut stats = self.store.stats();
        stats.set(
            "cache",
            cache::stats_value(
                self.cache_on,
                cache::combine(self.merge_cache.stats(), self.topk_cache.stats()),
            ),
        );
        stats
    }

    fn execute_inner(
        &self,
        req: Request,
        scratch: &mut SketchScratch,
    ) -> anyhow::Result<Response> {
        Ok(match req {
            Request::Ping => Response::Pong,
            Request::Hello => Response::Hello { info: self.hello() },
            Request::Metrics => {
                self.observe_store();
                let mut snap = self.metrics.snapshot();
                snap.set("sketches", crate::util::json::Value::num(self.registry.sketch_count() as f64));
                snap.set("streams", crate::util::json::Value::num(self.registry.stream_count() as f64));
                snap.set("store", self.store_stats_with_cache());
                snap.set("accel", crate::util::json::Value::Bool(self.accel_on));
                snap.set("shards", crate::util::json::Value::num(self.cfg.shards as f64));
                snap.set("algo", crate::util::json::Value::str(self.default_algo.name()));
                snap.set("node", crate::util::json::Value::str(self.cfg.node_id.clone()));
                snap.set("epoch", crate::util::json::Value::num(self.epoch() as f64));
                snap.set(
                    "batch_flushes",
                    crate::util::json::Value::num(
                        self.batcher.flushes.load(std::sync::atomic::Ordering::Relaxed) as f64,
                    ),
                );
                Response::MetricsDump { snapshot: snap }
            }
            Request::Sketch { name, vector, algo } => {
                let sk = self.sketch_sparse(&vector, algo.as_deref(), scratch)?;
                self.registry.put_sketch(&name, sk.clone());
                Response::Sketch { name, sketch: sk }
            }
            Request::SketchDense { name, weights } => {
                // Router decides engine; both produce Direct-family
                // sketches via the batcher (accel or CPU fallback).
                let _path = self.router.route_dense(weights.len());
                let rx = self.batcher.submit(weights);
                let sk = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("batcher dropped request"))??;
                self.registry.put_sketch(&name, sk.clone());
                Response::Sketch { name, sketch: sk }
            }
            Request::GetSketch { name } => {
                let sk = self
                    .registry
                    .get_sketch(&name)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{name}'"))?;
                Response::Sketch { name, sketch: sk }
            }
            Request::SketchFetch { name, source } => {
                let (version, sk) = self.fetch_sketch(&name, source)?;
                let data = codec::encode_sketch_hex(&name, version, &sk);
                Response::SketchBlob { name, data }
            }
            Request::SketchFetchBin { name, source } => {
                // Same lookup, raw container bytes: the framed transport
                // splices `data` into the response frame verbatim, so the
                // encode below is the only serialization the blob sees.
                let (version, sk) = self.fetch_sketch(&name, source)?;
                let data = codec::encode_sketch_bytes(&name, version, &sk);
                Response::SketchBlobBin { name, data }
            }
            Request::Push { stream, items } => {
                let n = self.registry.stream_push(&stream, self.cfg.k, self.cfg.seed, &items);
                Response::Ack { info: format!("stream '{stream}' processed {n}") }
            }
            Request::Cardinality { stream } => {
                let sk = self
                    .registry
                    .stream_sketch(&stream)
                    .ok_or_else(|| anyhow::anyhow!("no stream named '{stream}'"))?;
                Response::Estimate { value: estimate_cardinality(&sk) }
            }
            Request::Jaccard { a, b } => {
                let sa = self
                    .registry
                    .get_sketch(&a)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{a}'"))?;
                let sb = self
                    .registry
                    .get_sketch(&b)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{b}'"))?;
                Response::Estimate { value: estimate_jp(&sa, &sb)? }
            }
            Request::WeightedJaccard { a, b } => {
                let sa = self
                    .registry
                    .get_sketch(&a)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{a}'"))?;
                let sb = self
                    .registry
                    .get_sketch(&b)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{b}'"))?;
                Response::Estimate { value: estimate_weighted_jaccard(&sa, &sb)? }
            }
            Request::Merge { names, out } => {
                anyhow::ensure!(!names.is_empty(), "merge needs at least one sketch");
                let sketches: Vec<_> = names
                    .iter()
                    .map(|n| {
                        self.registry
                            .get_sketch(n)
                            .ok_or_else(|| anyhow::anyhow!("no sketch named '{n}'"))
                    })
                    .collect::<anyhow::Result<_>>()?;
                let merged = merge_tree(&sketches, 4)?;
                self.registry.put_sketch(&out, merged.clone());
                Response::Sketch { name: out, sketch: merged }
            }
            Request::LshInsert { name } => {
                let sk = self
                    .registry
                    .get_sketch(&name)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{name}'"))?;
                // LshQuery always sketches the probe with the *default*
                // algo, so an index entry from any other family/seed/k can
                // never legitimately match — reject at insert instead of
                // silently never returning it (or erroring mid-query).
                let want = self.default_algo.family();
                self.ensure_lsh_capable()?;
                anyhow::ensure!(
                    sk.family == want && sk.seed == self.cfg.seed && sk.k() == self.cfg.k,
                    "LSH index accepts only default-algo sketches \
                     (family '{}', seed {}, k {}); '{name}' is family '{}', seed {}, k {}",
                    want.name(),
                    self.cfg.seed,
                    self.cfg.k,
                    sk.family.name(),
                    sk.seed,
                    sk.k(),
                );
                let key = token_id(&name);
                self.lsh.write().unwrap().insert(key, sk);
                self.lsh_names.write().unwrap().insert(key, name.clone());
                Response::Ack { info: format!("indexed '{name}'") }
            }
            Request::LshQuery { vector, limit } => {
                self.ensure_lsh_capable()?;
                let query = self.sketch_sparse(&vector, None, scratch)?;
                let hits = self.lsh.read().unwrap().query(&query, limit)?;
                let names = self.lsh_names.read().unwrap();
                Response::TopK {
                    hits: hits
                        .into_iter()
                        .map(|(key, score)| {
                            (
                                names.get(&key).cloned().unwrap_or_else(|| format!("#{key}")),
                                score,
                            )
                        })
                        .collect(),
                }
            }
            Request::Upsert { key, vector, version } => {
                // The store is queried with default-algo probes, so every
                // entry is sketched with the default algo — the store can
                // never hold a sketch a `topk` could not score.
                self.ensure_lsh_capable()?;
                // The snapshot codec refuses oversized keys on decode;
                // enforcing the same bound here means every acked upsert
                // is guaranteed snapshot-and-restorable.
                anyhow::ensure!(
                    key.len() <= codec::MAX_KEY_LEN,
                    "store keys are limited to {} bytes (got {})",
                    codec::MAX_KEY_LEN,
                    key.len(),
                );
                let sk = self.sketch_sparse(&vector, None, scratch)?;
                self.metrics.incr("store.upsert");
                match version {
                    None => {
                        let v = self.store.upsert(&key, sk);
                        Response::Ack { info: format!("upserted '{key}' @v{v}") }
                    }
                    Some(v) => match self.store.put_versioned(&key, v, sk) {
                        Some(v) => Response::Ack { info: format!("upserted '{key}' @v{v}") },
                        // Stale-by-version is a SUCCESSFUL no-op, not an
                        // error: LWW means the write is superseded, and a
                        // replica replaying old traffic must not alarm.
                        None => Response::Ack {
                            info: format!(
                                "kept '{key}' @v{} (stale write v{v})",
                                self.store.version_of(&key).unwrap_or(0),
                            ),
                        },
                    },
                }
            }
            Request::StoreKeys { after, limit } => {
                anyhow::ensure!(limit >= 1, "store_keys needs a limit of at least 1");
                self.metrics.incr("store.keys");
                Response::Keys { keys: self.store.keys_page(after.as_deref(), limit) }
            }
            Request::StorePut { data } => {
                self.ensure_lsh_capable()?;
                let (key, version, sk) = codec::decode_sketch_hex(&data)?;
                self.store_put_sketch(key, version, sk)?
            }
            Request::StorePutBin { data } => {
                self.ensure_lsh_capable()?;
                let (key, version, sk) = codec::decode_sketch_bytes(&data)?;
                self.store_put_sketch(key, version, sk)?
            }
            Request::StreamMerge { stream, data } => {
                let (_, _, sk) = codec::decode_sketch_hex(&data)?;
                self.stream_merge_sketch(stream, &sk)?
            }
            Request::StreamMergeBin { stream, data } => {
                let (_, _, sk) = codec::decode_sketch_bytes(&data)?;
                self.stream_merge_sketch(stream, &sk)?
            }
            Request::Delete { key } => {
                let existed = self.store.delete(&key);
                self.metrics.incr("store.delete");
                Response::Ack {
                    info: if existed {
                        format!("deleted '{key}'")
                    } else {
                        format!("no entry '{key}'")
                    },
                }
            }
            Request::TopK { vector, limit } => {
                self.ensure_lsh_capable()?;
                let query = self.sketch_sparse(&vector, None, scratch)?;
                // Probe-then-fill: the ranking cache is keyed by a digest
                // of every query register bit + the limit, and tagged with
                // the per-shard write generations snapshotted BEFORE the
                // ranking runs — any store write since then invalidates
                // (the ranking read every entry, so whole-store granularity
                // is exact, not conservative).
                let digest = self.cache_on.then(|| {
                    let mut d = Digest::new();
                    d.u64(limit as u64);
                    for &y in &query.y {
                        d.f64(y);
                    }
                    for &s in &query.s {
                        d.u64(s);
                    }
                    d.finish()
                });
                if let Some(digest) = digest {
                    if let Some(hit) = self.topk_cache.get_validated(digest, |e| {
                        self.store.generations() == e.gens
                    }) {
                        self.metrics.incr("path.topk.cached");
                        return Ok(Response::TopK { hits: hit.hits.clone() });
                    }
                }
                let gens =
                    if digest.is_some() { self.store.generations() } else { Vec::new() };
                let shape = QueryShape::Rank { store_len: self.store.len() };
                let (hits, stats) = match self.router.plan_query(shape) {
                    QueryPlan::FullScan => {
                        self.metrics.incr("path.topk.scan");
                        self.store.scan_topk(&query, limit)?
                    }
                    QueryPlan::BandProbe => {
                        self.metrics.incr("path.topk.probe");
                        self.store.probe_topk(&query, limit)?
                    }
                    plan => anyhow::bail!("planner returned {plan:?} for a ranking query"),
                };
                self.metrics.add("topk.candidates", stats.candidates as u64);
                self.metrics.add("topk.reranked", stats.reranked as u64);
                if let Some(digest) = digest {
                    let cost = 64
                        + gens.len() * 8
                        + hits.iter().map(|(name, _)| name.len() + 32).sum::<usize>();
                    self.topk_cache.insert(
                        digest,
                        Arc::new(TopKEntry { hits: hits.clone(), gens }),
                        cost,
                    );
                }
                Response::TopK { hits }
            }
            Request::Sample { target, n, seed } => {
                anyhow::ensure!(n >= 1, "sample needs n of at least 1");
                let sk = self.read_query_target(&target)?;
                let ids = sample::sample_n(&sk, n, seed)?;
                self.metrics.incr("query.sample");
                self.metrics.add("sample.draws", ids.len() as u64);
                Response::Samples { ids }
            }
            Request::Partition { target } => {
                let sk = self.read_query_target(&target)?;
                let value = sample::total_weight(&sk)?;
                self.metrics.incr("query.partition");
                Response::Estimate { value }
            }
            Request::StoreStats => Response::Stats { stats: self.store_stats_with_cache() },
            Request::Snapshot { path } => {
                let (bytes, entries) = self.store.snapshot_bytes();
                // Write-then-rename so a crash or full disk mid-write can
                // never destroy an existing good snapshot at `path`; the
                // temp name is unique per request so concurrent snapshots
                // to the same path cannot interleave into a corrupt file.
                static SNAP_SEQ: AtomicU64 = AtomicU64::new(0);
                let seq = SNAP_SEQ.fetch_add(1, Ordering::Relaxed);
                let tmp = format!("{path}.tmp.{}.{seq}", std::process::id());
                // write + fsync + rename: without the fsync the rename can
                // survive a crash whose page-cache data did not, replacing
                // the old good snapshot with a truncated file.
                let write_synced = || -> std::io::Result<()> {
                    use std::io::Write as _;
                    let mut f = std::fs::File::create(&tmp)?;
                    f.write_all(&bytes)?;
                    f.sync_all()
                };
                write_synced().map_err(|e| {
                    let _ = std::fs::remove_file(&tmp);
                    anyhow::anyhow!("cannot write snapshot '{tmp}': {e}")
                })?;
                std::fs::rename(&tmp, &path).map_err(|e| {
                    let _ = std::fs::remove_file(&tmp);
                    anyhow::anyhow!("cannot finalize snapshot '{path}': {e}")
                })?;
                self.metrics.incr("store.snapshot");
                Response::Ack {
                    info: format!("snapshot '{path}': {entries} entries, {} bytes", bytes.len()),
                }
            }
            Request::Restore { path } => {
                self.ensure_lsh_capable()?;
                let bytes = std::fs::read(&path)
                    .map_err(|e| anyhow::anyhow!("cannot read snapshot '{path}': {e}"))?;
                let n = self.store.restore_bytes(
                    &bytes,
                    Some((self.default_algo.family(), self.cfg.seed, self.cfg.k)),
                )?;
                self.metrics.incr("store.restore");
                // State replaced: every cached tag is now unprovable
                // (restore bumped the version-drop and shard generations),
                // so validation would reject each entry on its next probe —
                // clearing now just returns the memory immediately.
                self.merge_cache.clear();
                self.topk_cache.clear();
                self.neg_cache.clear();
                // A new epoch, visible through `hello`.
                self.epoch.fetch_add(1, Ordering::SeqCst);
                Response::Ack { info: format!("restored {n} entries from '{path}'") }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Family;

    fn node() -> Node {
        Node::new(CoordinatorConfig {
            k: 64,
            node_id: "n-test".into(),
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    fn vec1() -> SparseVector {
        SparseVector::new(vec![1, 2, 3, 4], vec![1.0, 0.5, 2.0, 1.0])
    }

    /// The whole request surface is reachable with no socket, no worker
    /// pool and no transport — the refactor's reason to exist.
    #[test]
    fn node_executes_requests_without_any_transport() {
        let n = node();
        assert_eq!(n.execute_alloc(Request::Ping), Response::Pong);
        let Response::Sketch { sketch, .. } = n.execute_alloc(Request::Sketch {
            name: "u".into(),
            vector: vec1(),
            algo: None,
        }) else {
            panic!("expected sketch")
        };
        assert_eq!(sketch.family, Family::Ordered);
        assert_eq!(sketch.k(), 64);
        // Errors are responses, not panics — same contract as the service.
        assert!(matches!(
            n.execute_alloc(Request::GetSketch { name: "ghost".into() }),
            Response::Error { .. }
        ));
        n.shutdown();
    }

    #[test]
    fn hello_reports_identity_config_and_epoch() {
        let n = node();
        let Response::Hello { info } = n.execute_alloc(Request::Hello) else {
            panic!("expected hello")
        };
        assert_eq!(info.protocol, PROTOCOL_VERSION);
        assert_eq!(info.node, "n-test");
        assert_eq!(info.epoch, 0);
        assert_eq!(info.k, 64);
        assert_eq!(info.seed, 42);
        assert_eq!(info.algo, "fastgm");
        let want: Vec<String> =
            AlgorithmId::ALL.iter().map(|a| a.name().to_string()).collect();
        assert_eq!(info.algos, want);
        assert_eq!(info, n.hello(), "wire hello and typed hello must agree");
        n.shutdown();
    }

    #[test]
    fn restore_bumps_the_epoch() {
        let path = std::env::temp_dir().join(format!(
            "fastgm-node-epoch-{}.fgms",
            std::process::id()
        ));
        let path_str = path.to_string_lossy().to_string();
        let n = node();
        n.execute_alloc(Request::Upsert { key: "a".into(), vector: vec1(), version: None });
        assert!(matches!(
            n.execute_alloc(Request::Snapshot { path: path_str.clone() }),
            Response::Ack { .. }
        ));
        assert_eq!(n.epoch(), 0);
        for round in 1..=2u64 {
            assert!(matches!(
                n.execute_alloc(Request::Restore { path: path_str.clone() }),
                Response::Ack { .. }
            ));
            assert_eq!(n.epoch(), round);
        }
        // A failed restore does not bump the epoch.
        assert!(matches!(
            n.execute_alloc(Request::Restore { path: "/no/such.fgms".into() }),
            Response::Error { .. }
        ));
        assert_eq!(n.epoch(), 2);
        n.shutdown();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn sketch_fetch_serves_all_three_sources_bit_identically() {
        let n = node();
        let v = vec1();
        // store / registry / stream each get a sketch under the same name.
        n.execute_alloc(Request::Upsert { key: "x".into(), vector: v.clone(), version: None });
        n.execute_alloc(Request::Sketch { name: "x".into(), vector: v.clone(), algo: None });
        n.execute_alloc(Request::Push {
            stream: "x".into(),
            items: v.ids.iter().zip(&v.weights).map(|(&i, &w)| (i, w)).collect(),
        });
        for source in [SketchSource::Store, SketchSource::Registry, SketchSource::Stream] {
            let Response::SketchBlob { name, data } =
                n.execute_alloc(Request::SketchFetch { name: "x".into(), source })
            else {
                panic!("expected blob for {source:?}")
            };
            assert_eq!(name, "x");
            let (key, version, sk) = codec::decode_sketch_hex(&data).unwrap();
            assert_eq!(key, "x");
            // Store blobs carry the write version; the other sources say 0.
            let want_version = if source == SketchSource::Store { 1 } else { 0 };
            assert_eq!(version, want_version, "{source:?}");
            assert_eq!(sk.k(), 64);
            assert_eq!(sk.seed, 42);
            assert_eq!(sk.family, Family::Ordered);
        }
        // Unknown names are per-source errors.
        let resp = n.execute_alloc(Request::SketchFetch {
            name: "nope".into(),
            source: SketchSource::Stream,
        });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("no stream sketch named 'nope'"), "{message}");
        n.shutdown();
    }

    /// The query engine's new ops: `sample`/`partition` resolve key-set
    /// and stream targets through the plan/execute seam, reproduce by
    /// seed, and match calling the estimators on the merged sketch
    /// directly (the wire ops are thin shims over `estimate::sample`).
    #[test]
    fn sample_and_partition_serve_keys_and_streams() {
        let nd = node();
        let va = SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
        let vb = SparseVector::new(vec![3, 4], vec![1.5, 1.0]);
        nd.execute_alloc(Request::Upsert { key: "a".into(), vector: va, version: None });
        nd.execute_alloc(Request::Upsert { key: "b".into(), vector: vb, version: None });
        let draw = |target: QueryTarget, count: usize, seed: u64| -> Vec<u64> {
            match nd.execute_alloc(Request::Sample { target, n: count, seed }) {
                Response::Samples { ids } => ids,
                other => panic!("expected samples, got {other:?}"),
            }
        };
        // Single-key sampling: seed-reproducible, ids from the vector.
        let one = draw(QueryTarget::key("a"), 16, 7);
        assert_eq!(one, draw(QueryTarget::key("a"), 16, 7));
        assert!(one.iter().all(|id| [1, 2, 3].contains(id)));
        // Key-set sampling equals sampling the §2.3 union directly.
        let keys = vec!["a".to_string(), "b".to_string()];
        let (merged, _) = nd.store.merge_keys(&keys).unwrap();
        assert_eq!(
            draw(QueryTarget::Keys(keys.clone()), 32, 11),
            sample::sample_n(&merged, 32, 11).unwrap()
        );
        // Partition over the key set equals the estimator on the merge.
        let Response::Estimate { value } =
            nd.execute_alloc(Request::Partition { target: QueryTarget::Keys(keys) })
        else {
            panic!("expected estimate")
        };
        assert_eq!(value, sample::total_weight(&merged).unwrap());
        assert!(value > 0.0 && value.is_finite());
        // Stream targets read the live stream state.
        nd.execute_alloc(Request::Push {
            stream: "s".into(),
            items: vec![(10, 1.0), (11, 2.0)],
        });
        let s = draw(QueryTarget::Stream("s".into()), 8, 3);
        assert!(s.iter().all(|id| [10, 11].contains(id)));
        assert!(matches!(
            nd.execute_alloc(Request::Partition { target: QueryTarget::Stream("s".into()) }),
            Response::Estimate { .. }
        ));
        // Unknown targets and a zero draw count are loud errors.
        for (req, want) in [
            (
                Request::Sample { target: QueryTarget::key("ghost"), n: 4, seed: 0 },
                "no store entry 'ghost'",
            ),
            (
                Request::Sample { target: QueryTarget::Stream("ghost".into()), n: 4, seed: 0 },
                "no stream named 'ghost'",
            ),
            (
                Request::Sample { target: QueryTarget::key("a"), n: 0, seed: 0 },
                "at least 1",
            ),
        ] {
            let resp = nd.execute_alloc(req);
            let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
            assert!(message.contains(want), "{message}");
        }
        nd.shutdown();
    }

    /// Cached reads may only ever change latency, never a bit: for both
    /// EXP-register families, `sample`/`partition`/`topk` answers from a
    /// cache-enabled node equal a cache-disabled node's — on the fill, on
    /// the hit, after an interleaved write, and after the delete +
    /// re-upsert sequence that resets the key's version run (the case the
    /// version-drop generation exists for: without it the re-upserted key
    /// comes back at v1 and a `(key, v1)` tag from the *old* v1 contents
    /// would wrongly validate).
    #[test]
    fn cached_reads_are_bit_identical_to_fresh_across_families() {
        for algo in ["fastgm", "pminhash"] {
            let cached = Node::new(CoordinatorConfig {
                k: 64,
                algo: algo.into(),
                ..CoordinatorConfig::default()
            })
            .unwrap();
            let fresh = Node::new(CoordinatorConfig {
                k: 64,
                algo: algo.into(),
                cache_enabled: false,
                ..CoordinatorConfig::default()
            })
            .unwrap();
            let va = SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
            let vb = SparseVector::new(vec![3, 4], vec![1.5, 1.0]);
            let vc = SparseVector::new(vec![5, 6, 7], vec![0.5, 0.5, 3.0]);
            let both = |req: Request| {
                let a = cached.execute_alloc(req.clone());
                let b = fresh.execute_alloc(req.clone());
                assert_eq!(a, b, "[{algo}] cached and fresh answers diverge for {req:?}");
                a
            };
            let upsert = |key: &str, v: &SparseVector| {
                both(Request::Upsert { key: key.into(), vector: v.clone(), version: None });
            };
            upsert("a", &va);
            upsert("b", &vb);
            // Duplicated, unsorted key lists normalize to the same entry.
            let keys = || QueryTarget::Keys(vec!["b".into(), "a".into(), "b".into()]);
            let probe = |tag: &str| {
                for _round in 0..2 {
                    both(Request::Sample { target: keys(), n: 32, seed: 9 });
                    both(Request::Partition { target: keys() });
                    both(Request::TopK { vector: va.clone(), limit: 2 });
                }
                assert!(
                    matches!(both(Request::Sample { target: keys(), n: 8, seed: 1 }),
                        Response::Samples { .. }),
                    "[{algo}] {tag}: probes must succeed"
                );
            };
            probe("initial fill + hit");
            // Delete + re-upsert with DIFFERENT contents lands back at v1 —
            // the exact version the cached tag holds, so only the
            // version-drop generation can reject the stale entry.
            both(Request::Delete { key: "b".into() });
            upsert("b", &vc);
            assert_eq!(cached.store.version_of("b"), Some(1), "[{algo}]");
            probe("after delete + re-upsert at the same version");
            // A plain write to a member key must invalidate too.
            upsert("b", &va);
            assert_eq!(cached.store.version_of("b"), Some(2), "[{algo}]");
            probe("after version bump");
            // The hit path actually ran (this test would pass vacuously
            // against an always-miss cache).
            assert!(
                cached.metrics().counter("path.query.merge_cached") >= 3,
                "[{algo}] merge cache never hit"
            );
            assert!(
                cached.metrics().counter("path.topk.cached") >= 1,
                "[{algo}] topk cache never hit"
            );
            assert_eq!(fresh.metrics().counter("path.query.merge_cached"), 0);
            cached.shutdown();
            fresh.shutdown();
        }
    }

    /// A writer racing a `sample --keys` loop can never make the cache
    /// serve a stale union: the member key only ever holds one of two
    /// known vectors, so every sampled answer must equal the fresh-merge
    /// answer for one of those two states — and once the writer stops, the
    /// answer must equal the final state's exactly.
    #[test]
    fn racing_writer_never_yields_a_stale_cached_union() {
        let n = node();
        let va = SparseVector::new(vec![1, 2], vec![1.0, 1.0]);
        let vb1 = SparseVector::new(vec![10, 11], vec![1.0, 2.0]);
        let vb2 = SparseVector::new(vec![20, 21], vec![2.0, 1.0]);
        let up = |key: &str, v: &SparseVector| {
            n.execute_alloc(Request::Upsert { key: key.into(), vector: v.clone(), version: None });
        };
        up("a", &va);
        let keys = vec!["a".to_string(), "b".to_string()];
        // The only two answers a consistent union can produce.
        let expected: Vec<Vec<u64>> = [&vb1, &vb2]
            .iter()
            .map(|vb| {
                up("b", vb);
                let (merged, _) = n.store.merge_keys(&keys).unwrap();
                sample::sample_n(&merged, 16, 5).unwrap()
            })
            .collect();
        assert_ne!(expected[0], expected[1], "states must be distinguishable");
        const ROUNDS: usize = 400;
        std::thread::scope(|scope| {
            let writer = scope.spawn(|| {
                for i in 0..ROUNDS {
                    n.execute_alloc(Request::Upsert {
                        key: "b".into(),
                        vector: if i % 2 == 0 { vb1.clone() } else { vb2.clone() },
                        version: None,
                    });
                }
            });
            for _ in 0..ROUNDS {
                let Response::Samples { ids } = n.execute_alloc(Request::Sample {
                    target: QueryTarget::Keys(keys.clone()),
                    n: 16,
                    seed: 5,
                }) else {
                    panic!("expected samples")
                };
                assert!(
                    ids == expected[0] || ids == expected[1],
                    "stale or torn union served: {ids:?}"
                );
            }
            writer.join().unwrap();
        });
        // Quiesced: the cache must now agree with the writer's last state
        // (ROUNDS even → last write was vb2).
        let Response::Samples { ids } = n.execute_alloc(Request::Sample {
            target: QueryTarget::Keys(keys.clone()),
            n: 16,
            seed: 5,
        }) else {
            panic!("expected samples")
        };
        assert_eq!(ids, expected[1], "post-race answer must match the final state");
        n.shutdown();
    }

    /// The cache surfaces through both stats ops: `store_stats` and
    /// `metrics` embed the same `cache` object, hit/miss/bytes move, and
    /// `restore` clears the cache outright.
    #[test]
    fn cache_stats_surface_and_restore_clears() {
        let path = std::env::temp_dir().join(format!(
            "fastgm-node-cache-{}.fgms",
            std::process::id()
        ));
        let path_str = path.to_string_lossy().to_string();
        let n = node();
        n.execute_alloc(Request::Upsert { key: "a".into(), vector: vec1(), version: None });
        let sample = || {
            n.execute_alloc(Request::Sample {
                target: QueryTarget::key("a"),
                n: 4,
                seed: 0,
            })
        };
        sample(); // miss + fill
        sample(); // hit
        let Response::Stats { stats } = n.execute_alloc(Request::StoreStats) else {
            panic!("expected stats")
        };
        let cache = stats.get("cache").expect("store_stats must embed the cache object");
        let field = |name: &str| cache.get(name).and_then(|v| v.as_f64()).unwrap();
        assert_eq!(cache.get("enabled").and_then(|v| v.as_bool()), Some(true));
        assert!(field("hits") >= 1.0, "{stats}");
        assert!(field("misses") >= 1.0, "{stats}");
        assert!(field("bytes") > 0.0, "{stats}");
        assert!(field("entries") >= 1.0, "{stats}");
        // The metrics op embeds the identical object + the cache gauges.
        let Response::MetricsDump { snapshot } = n.execute_alloc(Request::Metrics) else {
            panic!("expected metrics")
        };
        assert_eq!(
            snapshot.get("store").and_then(|s| s.get("cache")).map(|v| v.to_string()),
            Some(cache.to_string()),
            "metrics and store_stats disagree about the cache"
        );
        let gauge = |name: &str| {
            snapshot.get("gauges").and_then(|g| g.get(name)).and_then(|v| v.as_f64())
        };
        assert_eq!(gauge("cache.hit"), Some(field("hits")), "{snapshot}");
        assert_eq!(gauge("cache.bytes"), Some(field("bytes")), "{snapshot}");
        assert!(gauge("cache.miss").is_some() && gauge("cache.evict").is_some());
        assert!(gauge("cache.stale_drop").is_some());
        // Restore drops every cached entry immediately.
        n.execute_alloc(Request::Snapshot { path: path_str.clone() });
        n.execute_alloc(Request::Restore { path: path_str });
        let Response::Stats { stats } = n.execute_alloc(Request::StoreStats) else {
            panic!("expected stats")
        };
        let cache = stats.get("cache").unwrap();
        assert_eq!(cache.get("entries").and_then(|v| v.as_f64()), Some(0.0), "{stats}");
        assert_eq!(cache.get("bytes").and_then(|v| v.as_f64()), Some(0.0), "{stats}");
        n.shutdown();
        let _ = std::fs::remove_file(path);
    }

    /// The anti-entropy surface end to end on one node: versioned upserts,
    /// the key walk, LWW blob installs and stream merges.
    #[test]
    fn repair_ops_walk_install_and_merge() {
        let n = node();
        let v = vec1();
        // Two writes → version 2; an explicit stale write is a kept-ack.
        for want in ["@v1", "@v2"] {
            let Response::Ack { info } = n.execute_alloc(Request::Upsert {
                key: "a".into(),
                vector: v.clone(),
                version: None,
            }) else {
                panic!("expected ack")
            };
            assert!(info.contains(want), "{info}");
        }
        let Response::Ack { info } = n.execute_alloc(Request::Upsert {
            key: "a".into(),
            vector: v.clone(),
            version: Some(1),
        }) else {
            panic!("expected ack")
        };
        assert!(info.contains("kept 'a' @v2"), "{info}");
        n.execute_alloc(Request::Upsert { key: "b".into(), vector: v.clone(), version: None });
        // The key walk pages in order with versions.
        let Response::Keys { keys } =
            n.execute_alloc(Request::StoreKeys { after: None, limit: 10 })
        else {
            panic!("expected keys")
        };
        assert_eq!(keys, vec![("a".to_string(), 2), ("b".to_string(), 1)]);
        let Response::Keys { keys } =
            n.execute_alloc(Request::StoreKeys { after: Some("a".into()), limit: 10 })
        else {
            panic!("expected keys")
        };
        assert_eq!(keys, vec![("b".to_string(), 1)]);
        assert!(matches!(
            n.execute_alloc(Request::StoreKeys { after: None, limit: 0 }),
            Response::Error { .. }
        ));
        // store_put: a newer blob installs, a stale one is kept, a blob at
        // the wrong sketch config is a loud error.
        let Response::SketchBlob { data, .. } = n.execute_alloc(Request::SketchFetch {
            name: "a".into(),
            source: SketchSource::Store,
        }) else {
            panic!("expected blob")
        };
        let (_, _, sk) = codec::decode_sketch_hex(&data).unwrap();
        let newer = codec::encode_sketch_hex("a", 9, &sk);
        let Response::Ack { info } = n.execute_alloc(Request::StorePut { data: newer }) else {
            panic!("expected ack")
        };
        assert!(info.contains("installed 'a' @v9"), "{info}");
        let stale = codec::encode_sketch_hex("a", 3, &sk);
        let Response::Ack { info } = n.execute_alloc(Request::StorePut { data: stale }) else {
            panic!("expected ack")
        };
        assert!(info.contains("kept 'a' @v9"), "{info}");
        let wrong_cfg = codec::encode_sketch_hex(
            "a",
            99,
            &crate::sketch::fastgm::FastGm::new(32, 42).sketch(&v),
        );
        let resp = n.execute_alloc(Request::StorePut { data: wrong_cfg });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("does not match"), "{message}");
        assert!(matches!(
            n.execute_alloc(Request::StorePut { data: "zz".into() }),
            Response::Error { .. }
        ));
        // stream_merge: a peer's stream sketch is absorbed (§2.3), so the
        // merged stream equals the union stream bit-identically.
        n.execute_alloc(Request::Push { stream: "s".into(), items: vec![(1, 0.5)] });
        let mut peer = crate::sketch::stream_fastgm::StreamFastGm::new(64, 42);
        peer.push(2, 1.5);
        let blob = codec::encode_sketch_hex("s", 0, &peer.sketch());
        assert!(matches!(
            n.execute_alloc(Request::StreamMerge { stream: "s".into(), data: blob }),
            Response::Ack { .. }
        ));
        let Response::SketchBlob { data, .. } = n.execute_alloc(Request::SketchFetch {
            name: "s".into(),
            source: SketchSource::Stream,
        }) else {
            panic!("expected blob")
        };
        let (_, _, merged) = codec::decode_sketch_hex(&data).unwrap();
        let mut union = crate::sketch::stream_fastgm::StreamFastGm::new(64, 42);
        union.push(1, 0.5);
        union.push(2, 1.5);
        assert_eq!(merged, union.sketch());
        // A mismatched-seed stream blob is refused.
        let bad = codec::encode_sketch_hex(
            "s",
            0,
            &crate::sketch::stream_fastgm::StreamFastGm::new(64, 7).sketch(),
        );
        assert!(matches!(
            n.execute_alloc(Request::StreamMerge { stream: "s".into(), data: bad }),
            Response::Error { .. }
        ));
        n.shutdown();
    }

    /// The binary blob ops serve byte-identical codec payloads to their
    /// hex twins and enforce the same gates: `sketch_fetch_bin` blobs are
    /// exactly the un-hexed `sketch_fetch` bytes for all three sources,
    /// `store_put_bin` installs/keeps/refuses like `store_put`, and
    /// `stream_merge_bin` converges to the same §2.3 union.
    #[test]
    fn binary_blob_ops_mirror_their_hex_twins_bit_for_bit() {
        let n = node();
        let v = vec1();
        n.execute_alloc(Request::Upsert { key: "x".into(), vector: v.clone(), version: None });
        n.execute_alloc(Request::Sketch { name: "x".into(), vector: v.clone(), algo: None });
        n.execute_alloc(Request::Push { stream: "x".into(), items: vec![(1, 0.5)] });
        for source in [SketchSource::Store, SketchSource::Registry, SketchSource::Stream] {
            let Response::SketchBlob { data: hex, .. } =
                n.execute_alloc(Request::SketchFetch { name: "x".into(), source })
            else {
                panic!("expected hex blob for {source:?}")
            };
            let Response::SketchBlobBin { name, data: raw } =
                n.execute_alloc(Request::SketchFetchBin { name: "x".into(), source })
            else {
                panic!("expected binary blob for {source:?}")
            };
            assert_eq!(name, "x");
            assert_eq!(codec::from_hex(&hex).unwrap(), raw, "{source:?}");
        }
        // Misses use the same per-source error text as the hex op.
        let resp = n.execute_alloc(Request::SketchFetchBin {
            name: "nope".into(),
            source: SketchSource::Stream,
        });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("no stream sketch named 'nope'"), "{message}");
        // store_put_bin: newer installs, stale is kept, wrong config and
        // garbage are loud errors — the hex op's exact contract.
        let Response::SketchBlobBin { data, .. } = n.execute_alloc(Request::SketchFetchBin {
            name: "x".into(),
            source: SketchSource::Store,
        }) else {
            panic!("expected blob")
        };
        let (_, _, sk) = codec::decode_sketch_bytes(&data).unwrap();
        let Response::Ack { info } = n.execute_alloc(Request::StorePutBin {
            data: codec::encode_sketch_bytes("x", 9, &sk),
        }) else {
            panic!("expected ack")
        };
        assert!(info.contains("installed 'x' @v9"), "{info}");
        let Response::Ack { info } = n.execute_alloc(Request::StorePutBin {
            data: codec::encode_sketch_bytes("x", 2, &sk),
        }) else {
            panic!("expected ack")
        };
        assert!(info.contains("kept 'x' @v9"), "{info}");
        let wrong_cfg = codec::encode_sketch_bytes(
            "x",
            99,
            &crate::sketch::fastgm::FastGm::new(32, 42).sketch(&v),
        );
        let resp = n.execute_alloc(Request::StorePutBin { data: wrong_cfg });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("does not match"), "{message}");
        assert!(matches!(
            n.execute_alloc(Request::StorePutBin { data: vec![0xde, 0xad] }),
            Response::Error { .. }
        ));
        // stream_merge_bin absorbs a peer blob into the same union the
        // hex op would produce.
        let mut peer = crate::sketch::stream_fastgm::StreamFastGm::new(64, 42);
        peer.push(2, 1.5);
        let blob = codec::encode_sketch_bytes("x", 0, &peer.sketch());
        assert!(matches!(
            n.execute_alloc(Request::StreamMergeBin { stream: "x".into(), data: blob }),
            Response::Ack { .. }
        ));
        let Response::SketchBlobBin { data, .. } = n.execute_alloc(Request::SketchFetchBin {
            name: "x".into(),
            source: SketchSource::Stream,
        }) else {
            panic!("expected blob")
        };
        let (_, _, merged) = codec::decode_sketch_bytes(&data).unwrap();
        let mut union = crate::sketch::stream_fastgm::StreamFastGm::new(64, 42);
        union.push(1, 0.5);
        union.push(2, 1.5);
        assert_eq!(merged, union.sketch());
        n.shutdown();
    }

    /// Negative caching (ROADMAP item 5): a repeated miss on a
    /// nonexistent key is served from the cache without re-probing the
    /// store, and ANY store write invalidates the cached miss instantly —
    /// the very next read sees the key.
    #[test]
    fn negative_cache_serves_repeat_misses_and_writes_invalidate() {
        let n = node();
        let fetch = |name: &str| {
            n.execute_alloc(Request::SketchFetch {
                name: name.into(),
                source: SketchSource::Store,
            })
        };
        // First miss probes the store and fills (neg_miss); the repeat is
        // served from the cache (neg_hit).
        assert!(matches!(fetch("ghost"), Response::Error { .. }));
        assert_eq!(n.metrics().counter("cache.neg_miss"), 1);
        assert_eq!(n.metrics().counter("cache.neg_hit"), 0);
        assert!(matches!(fetch("ghost"), Response::Error { .. }));
        assert_eq!(n.metrics().counter("cache.neg_hit"), 1);
        // Key-set queries over a missing member go negative too, with the
        // same error text the store merge produces.
        let q = Request::Sample { target: QueryTarget::key("ghost"), n: 2, seed: 0 };
        let Response::Error { message } = n.execute_alloc(q.clone()) else {
            panic!("expected error")
        };
        assert!(message.contains("no store entry 'ghost'"), "{message}");
        assert!(n.metrics().counter("cache.neg_hit") >= 2);
        // Writing the key invalidates the cached miss immediately.
        n.execute_alloc(Request::Upsert { key: "ghost".into(), vector: vec1(), version: None });
        assert!(matches!(fetch("ghost"), Response::SketchBlob { .. }));
        assert!(matches!(n.execute_alloc(q), Response::Samples { .. }));
        // A different key's write also invalidates (whole-store
        // generations, same tag the top-k cache uses) — absence is then
        // re-proved and re-cached.
        assert!(matches!(fetch("phantom"), Response::Error { .. }));
        n.execute_alloc(Request::Upsert { key: "other".into(), vector: vec1(), version: None });
        assert!(matches!(fetch("phantom"), Response::Error { .. }));
        assert_eq!(n.metrics().counter("cache.neg_miss"), 3);
        n.shutdown();
    }
}
