//! Keyed sketch store: the coordinator's first stateful subsystem.
//!
//! A sharded in-memory map from string keys to **versioned**
//! [`GumbelMaxSketch`]es with an **incrementally maintained** [`LshIndex`]
//! (upserts and deletes keep the band tables in sync — no rebuilds),
//! answering top-k similarity queries two ways:
//!
//! * [`SketchStore::probe_topk`] — banded LSH candidate probe, then a
//!   full-sketch `estimate_jp` re-rank of the (sub-linear) candidate set.
//! * [`SketchStore::scan_topk`] — brute-force re-rank of every entry; the
//!   router picks this for small stores where probing cannot win.
//!
//! Every key carries a monotonic write version: [`SketchStore::upsert`]
//! assigns `previous + 1`, [`SketchStore::put_versioned`] installs an
//! explicit version if (and only if) it is newer than what is held. The
//! version is what makes replicated serving deterministic — two replicas
//! of a key can always agree which copy is last-writer by comparing
//! versions, so the cluster's anti-entropy repair converges without
//! coordination. Deletes drop the version with the entry (no tombstones:
//! a repair can resurrect a key deleted on one replica while its peer was
//! down — documented in README §Replication).
//!
//! Persistence goes through [`crate::sketch::codec`]: `snapshot_bytes`
//! freezes the whole store into the versioned binary format (keys sorted,
//! so equal state ⇒ identical bytes, versions included) and
//! `restore_bytes` atomically replaces the store contents from a snapshot
//! — the warm-restart path that skips recomputing every sketch. v1
//! snapshots (pre-versioning) restore with every version at 0.
//!
//! Locking: keys are sharded over independent `RwLock<HashMap>`s so
//! concurrent upserts on different shards don't serialize; the LSH index
//! and the id→name map are single locks (band updates are cheap). An
//! outer swap `gate` is held shared by every keyed op and exclusively by
//! `restore`/`clear`, so a snapshot replacement is atomic as observed by
//! concurrent requests. Writers hold their key's shard lock across the
//! lsh/names updates (fixed order gate → shard → lsh → names) so the
//! map and index can never desync on same-key races; readers hold at
//! most one inner lock at a time — no cycle is possible.
//!
//! Memory trade-off: each sketch's registers live both in the shard map
//! (the source of truth for `get`/`scan`/`snapshot`) and inside the
//! [`LshIndex`] (whose standalone `query` API re-ranks from its own
//! copy). A bands-only index mode would halve that; it is a known
//! follow-up, not a correctness issue.

use crate::estimate::jaccard::estimate_jp_batch;
use crate::lsh::{LshIndex, LshParams};
use crate::sketch::codec;
use crate::sketch::{Family, GumbelMaxSketch, MergeError};
use crate::util::hash::token_id;
use crate::util::json::Value;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// What a top-k query cost, for the coordinator's metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopKStats {
    /// Entries that survived the LSH band probe (store size when scanning).
    pub candidates: usize,
    /// Candidates re-ranked with the full-sketch estimator.
    pub reranked: usize,
    /// True when the brute-force scan path answered the query.
    pub scanned: bool,
}

/// A stored sketch plus its monotonic write version.
#[derive(Debug, Clone, PartialEq)]
struct VersionedSketch {
    version: u64,
    sketch: GumbelMaxSketch,
}

pub struct SketchStore {
    lsh_params: LshParams,
    /// Swap gate: shared by every keyed op, exclusive for `restore`/`clear`
    /// — no request can ever observe a half-replaced store.
    gate: RwLock<()>,
    shards: Vec<RwLock<HashMap<String, VersionedSketch>>>,
    lsh: RwLock<LshIndex>,
    /// LSH ids are `token_id(key)`; this maps them back for responses.
    names: RwLock<HashMap<u64, String>>,
    /// Per-shard write generation, bumped inside the shard's write lock on
    /// every install/delete/clear. Whole-store answers (top-k rankings)
    /// are cache-tagged with a snapshot of these: any write anywhere
    /// invalidates, which is exactly right for a query that ranked every
    /// entry.
    gens: Vec<AtomicU64>,
    /// Version-drop generation, bumped on every delete/clear/restore. Per-
    /// key versions are only monotonic while the key exists — delete drops
    /// the version and the next write restarts at 1 (no tombstones), so a
    /// delete + re-upsert could make a stale `(key, version)` tag match
    /// again. Tagging cached merges with this counter closes that hole:
    /// upserts keep exact per-key invalidation, version-dropping events
    /// (rare) invalidate coarsely.
    delete_gen: AtomicU64,
}

impl SketchStore {
    pub fn new(lsh_params: LshParams, shards: usize) -> SketchStore {
        let shards = shards.max(1);
        SketchStore {
            lsh_params,
            gate: RwLock::new(()),
            shards: (0..shards).map(|_| RwLock::new(HashMap::new())).collect(),
            lsh: RwLock::new(LshIndex::new(lsh_params)),
            names: RwLock::new(HashMap::new()),
            gens: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            delete_gen: AtomicU64::new(0),
        }
    }

    pub fn lsh_params(&self) -> LshParams {
        self.lsh_params
    }

    fn shard_of(&self, key: &str) -> usize {
        (token_id(key) % self.shards.len() as u64) as usize
    }

    /// Insert or replace `key`'s sketch at the next write version
    /// (`previous + 1`, or 1 for a fresh key); the LSH index is updated in
    /// place. Returns the version assigned.
    pub fn upsert(&self, key: &str, sk: GumbelMaxSketch) -> u64 {
        let _gate = self.gate.read().expect("store gate");
        self.upsert_inner(key, None, sk).expect("next-version upsert always installs")
    }

    /// Install `key` at exactly `version` if it is strictly newer than the
    /// held copy (or the key is absent) — the deterministic last-writer-
    /// wins rule replicas converge by. Returns the installed version, or
    /// `None` (with the store untouched) when the put is stale.
    pub fn put_versioned(&self, key: &str, version: u64, sk: GumbelMaxSketch) -> Option<u64> {
        let _gate = self.gate.read().expect("store gate");
        self.upsert_inner(key, Some(version), sk)
    }

    /// Gate-free body shared by the public writers and the restore loop
    /// (which already holds the gate exclusively). The shard lock is held
    /// across the lsh/names updates so a same-key delete racing this
    /// upsert serializes with the whole triple — the map and index can
    /// never end up disagreeing about the key. `version: None` assigns
    /// `previous + 1`; `Some(v)` installs iff strictly newer.
    fn upsert_inner(&self, key: &str, version: Option<u64>, sk: GumbelMaxSketch) -> Option<u64> {
        let id = token_id(key);
        let idx = self.shard_of(key);
        let mut shard = self.shards[idx].write().expect("store shard lock");
        let held = shard.get(key).map(|v| v.version);
        let install = match version {
            None => held.map_or(1, |h| h + 1),
            Some(v) => {
                if held.is_some_and(|h| h >= v) {
                    return None; // stale: deterministic LWW keeps the held copy
                }
                v
            }
        };
        shard.insert(key.to_string(), VersionedSketch { version: install, sketch: sk.clone() });
        // Bumped inside the shard critical section, so a generation
        // snapshot validated under the shard lock can never miss a write
        // that the map already shows.
        self.gens[idx].fetch_add(1, Ordering::SeqCst);
        self.lsh.write().expect("store lsh lock").upsert(id, sk);
        self.names.write().expect("store names lock").insert(id, key.to_string());
        Some(install)
    }

    /// Remove `key`; returns whether it existed. Shard lock held across
    /// the index updates for the same reason as [`Self::upsert_inner`].
    pub fn delete(&self, key: &str) -> bool {
        let _gate = self.gate.read().expect("store gate");
        let idx = self.shard_of(key);
        let mut shard = self.shards[idx].write().expect("store shard lock");
        let existed = shard.remove(key).is_some();
        if existed {
            self.gens[idx].fetch_add(1, Ordering::SeqCst);
            self.delete_gen.fetch_add(1, Ordering::SeqCst);
            let id = token_id(key);
            self.lsh.write().expect("store lsh lock").remove(id);
            self.names.write().expect("store names lock").remove(&id);
        }
        existed
    }

    pub fn get(&self, key: &str) -> Option<GumbelMaxSketch> {
        self.get_versioned(key).map(|(_, sk)| sk)
    }

    /// `key`'s `(version, sketch)` pair — what the cluster's gather and
    /// repair paths move between sites.
    pub fn get_versioned(&self, key: &str) -> Option<(u64, GumbelMaxSketch)> {
        let _gate = self.gate.read().expect("store gate");
        self.shards[self.shard_of(key)]
            .read()
            .expect("store shard lock")
            .get(key)
            .map(|v| (v.version, v.sketch.clone()))
    }

    /// `key`'s current write version, without cloning registers.
    pub fn version_of(&self, key: &str) -> Option<u64> {
        let _gate = self.gate.read().expect("store gate");
        self.shards[self.shard_of(key)]
            .read()
            .expect("store shard lock")
            .get(key)
            .map(|v| v.version)
    }

    /// Snapshot of the per-shard write generations — the whole-store
    /// freshness tag for cached top-k results. Taken *before* running the
    /// query it tags: a write racing the query bumps its shard generation
    /// first (inside the shard lock), so the cached entry validates stale
    /// and is dropped rather than ever serving pre-write rankings as
    /// post-write state.
    pub fn generations(&self) -> Vec<u64> {
        self.gens.iter().map(|g| g.load(Ordering::SeqCst)).collect()
    }

    /// The version-drop counter cached merges are tagged with (see the
    /// `delete_gen` field: deletes reset per-key version sequences, so
    /// `(key, version)` tags alone cannot see delete + re-upsert).
    pub fn delete_generation(&self) -> u64 {
        self.delete_gen.load(Ordering::SeqCst)
    }

    /// Validate a cached merge's tag: true iff `delete_gen` still matches
    /// and every member key is held at exactly the tagged version. The
    /// seqlock-style re-check of `delete_gen` after the version pass
    /// closes the window where a member is deleted and re-upserted back to
    /// its tagged version between the first read and the shard reads (both
    /// bumps happen inside the shard critical section, so a shard read
    /// that observed the re-upsert happens-after the `delete_gen` bump).
    /// Total writes observed between the two reads invalidate — exactly
    /// the conservative direction.
    pub fn members_match(&self, members: &[(String, u64)], delete_gen: u64) -> bool {
        let _gate = self.gate.read().expect("store gate");
        if self.delete_gen.load(Ordering::SeqCst) != delete_gen {
            return false;
        }
        for (key, version) in members {
            let held = self.shards[self.shard_of(key)]
                .read()
                .expect("store shard lock")
                .get(key)
                .map(|v| v.version);
            if held != Some(*version) {
                return false;
            }
        }
        self.delete_gen.load(Ordering::SeqCst) == delete_gen
    }

    /// One page of the key range walk behind the `store_keys` op: up to
    /// `limit` `(key, version)` pairs with `key > after`, sorted by key —
    /// so a client pages the whole store with the last key as the next
    /// cursor, and two replicas can diff versions range by range.
    ///
    /// Bounded selection, not a full sort: only the `limit` smallest
    /// qualifying keys are ever held (a max-heap on the key), so one page
    /// over an N-key store costs O(N log limit) time and O(limit) clones
    /// — a full walk stays O(N²/limit · log limit) instead of cloning and
    /// sorting the whole remaining store once per page.
    pub fn keys_page(&self, after: Option<&str>, limit: usize) -> Vec<(String, u64)> {
        use std::collections::BinaryHeap;
        let _gate = self.gate.read().expect("store gate");
        // Max-heap ordered by key: the top is the LARGEST kept key, so a
        // smaller qualifying key evicts it once the page is full.
        let mut top: BinaryHeap<(String, u64)> = BinaryHeap::with_capacity(limit + 1);
        for shard in &self.shards {
            for (key, v) in shard.read().expect("store shard lock").iter() {
                if !after.map_or(true, |a| key.as_str() > a) {
                    continue;
                }
                if top.len() < limit {
                    top.push((key.clone(), v.version));
                } else if top.peek().is_some_and(|(worst, _)| key < worst) {
                    top.pop();
                    top.push((key.clone(), v.version));
                }
            }
        }
        let mut page = top.into_vec();
        page.sort_by(|a, b| a.0.cmp(&b.0));
        page
    }

    pub fn len(&self) -> usize {
        let _gate = self.gate.read().expect("store gate");
        self.shard_sizes_inner().iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        let _gate = self.gate.read().expect("store gate");
        self.shards.iter().all(|s| s.read().expect("store shard lock").is_empty())
    }

    pub fn shard_sizes(&self) -> Vec<usize> {
        let _gate = self.gate.read().expect("store gate");
        self.shard_sizes_inner()
    }

    fn shard_sizes_inner(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.read().expect("store shard lock").len()).collect()
    }

    /// Entries indexed for banded probing (tracks `len` by construction).
    pub fn lsh_len(&self) -> usize {
        let _gate = self.gate.read().expect("store gate");
        self.lsh.read().expect("store lsh lock").len()
    }

    fn rank(mut scored: Vec<(String, f64)>, limit: usize) -> Vec<(String, f64)> {
        // Deterministic order: score desc, then key asc — matches what a
        // brute-force scan produces, so probe and scan agree on ties.
        scored.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).expect("estimates are never NaN").then(a.0.cmp(&b.0))
        });
        scored.truncate(limit);
        scored
    }

    /// Top-`limit` via the banded LSH probe + full-sketch re-rank. The
    /// re-rank reads each candidate's registers in place under its shard
    /// lock (no clones); the sketch copy inside the LSH index is only used
    /// for band maintenance here.
    pub fn probe_topk(
        &self,
        query: &GumbelMaxSketch,
        limit: usize,
    ) -> Result<(Vec<(String, f64)>, TopKStats), MergeError> {
        let _gate = self.gate.read().expect("store gate");
        let candidate_ids = self.lsh.read().expect("store lsh lock").candidates(query);
        // Resolve every candidate under ONE names read guard, then score
        // under one shard guard at a time. Never two inner locks at once:
        // writers nest shard → lsh → names, so holding names while taking
        // a shard lock here could cycle. A candidate can vanish between
        // these steps (racing delete) — skip it, don't error the query.
        let resolved: Vec<String> = {
            let names = self.names.read().expect("store names lock");
            candidate_ids.iter().filter_map(|id| names.get(id).cloned()).collect()
        };
        // Group candidates by shard: each shard lock is taken once and its
        // candidates re-rank in one batched pass (vanished candidates are
        // skipped by the filter_map, exactly like the old per-key loop).
        let mut by_shard: Vec<Vec<String>> = vec![Vec::new(); self.shards.len()];
        for name in resolved {
            let idx = self.shard_of(&name);
            by_shard[idx].push(name);
        }
        let mut scored = Vec::new();
        for (idx, names) in by_shard.into_iter().enumerate() {
            if names.is_empty() {
                continue;
            }
            let shard = self.shards[idx].read().expect("store shard lock");
            let batch = estimate_jp_batch(
                query,
                names.into_iter().filter_map(|name| shard.get(&name).map(|v| (name, &v.sketch))),
            )?;
            drop(shard);
            scored.extend(batch);
        }
        let stats = TopKStats {
            candidates: candidate_ids.len(),
            reranked: scored.len(),
            scanned: false,
        };
        Ok((Self::rank(scored, limit), stats))
    }

    /// Union-merge the named keys' sketches (§2.3) for the key-set query
    /// ops (`sample`/`partition`): keys are grouped by shard, each shard
    /// lock is taken once, and every held sketch is merged in place into
    /// one accumulator — no register clones on the read path (the
    /// accumulator starts empty; `EMPTY_REGISTER` races lose every
    /// register, so the first merge is a plain copy). Returns the merged
    /// sketch plus each key's write version in **input order** (what a
    /// cluster client compares replica copies by). A missing key is a loud
    /// error: estimating over a silently shrunken union would bias the
    /// sample distribution instead of failing the query.
    pub fn merge_keys(&self, keys: &[String]) -> anyhow::Result<(GumbelMaxSketch, Vec<u64>)> {
        anyhow::ensure!(!keys.is_empty(), "merge_keys needs at least one key");
        let _gate = self.gate.read().expect("store gate");
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, key) in keys.iter().enumerate() {
            by_shard[self.shard_of(key)].push(i);
        }
        let mut versions = vec![0u64; keys.len()];
        let mut acc: Option<GumbelMaxSketch> = None;
        for (idx, members) in by_shard.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let shard = self.shards[idx].read().expect("store shard lock");
            for &i in members {
                let key = &keys[i];
                let v = shard
                    .get(key)
                    .ok_or_else(|| anyhow::anyhow!("no store entry '{key}'"))?;
                versions[i] = v.version;
                acc.get_or_insert_with(|| {
                    GumbelMaxSketch::empty(v.sketch.family, v.sketch.seed, v.sketch.k())
                })
                .merge_in_place(&v.sketch)?;
            }
        }
        Ok((acc.expect("non-empty keys imply an accumulator"), versions))
    }

    /// Top-`limit` by scoring every stored entry (exact, linear). Keys are
    /// *borrowed* through the batched estimator (`estimate_jp_batch` is
    /// generic over the key) and each shard's batch is ranked down to
    /// `limit` while its guard is still held, so only the per-shard
    /// winners are ever cloned — not one `String` per stored entry. The
    /// per-shard truncation is lossless: the global top-`limit` is a
    /// subset of the union of per-shard top-`limit`s, and the final
    /// [`Self::rank`] applies the identical score-desc/key-asc tie rule.
    pub fn scan_topk(
        &self,
        query: &GumbelMaxSketch,
        limit: usize,
    ) -> Result<(Vec<(String, f64)>, TopKStats), MergeError> {
        let _gate = self.gate.read().expect("store gate");
        let mut scored = Vec::new();
        let mut candidates = 0;
        for shard in &self.shards {
            let guard = shard.read().expect("store shard lock");
            let mut batch =
                estimate_jp_batch(query, guard.iter().map(|(name, v)| (name, &v.sketch)))?;
            candidates += batch.len();
            batch.sort_by(|a, b| {
                b.1.partial_cmp(&a.1).expect("estimates are never NaN").then(a.0.cmp(b.0))
            });
            batch.truncate(limit);
            scored.extend(batch.into_iter().map(|(name, score)| (name.clone(), score)));
        }
        let stats = TopKStats {
            candidates,
            reranked: candidates,
            scanned: true,
        };
        Ok((Self::rank(scored, limit), stats))
    }

    /// Freeze the store into the versioned binary snapshot format,
    /// returning the bytes and the number of entries they hold (counted in
    /// the same gated pass, so the two can never disagree). Keys are
    /// sorted, so two stores with equal contents — versions included —
    /// snapshot to identical bytes (the round-trip property tests and the
    /// repair-convergence acceptance test rely on this).
    pub fn snapshot_bytes(&self) -> (Vec<u8>, usize) {
        let _gate = self.gate.read().expect("store gate");
        let mut entries: Vec<(String, u64, GumbelMaxSketch)> = Vec::new();
        for shard in &self.shards {
            for (key, v) in shard.read().expect("store shard lock").iter() {
                entries.push((key.clone(), v.version, v.sketch.clone()));
            }
        }
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let n = entries.len();
        (codec::encode_store(&entries), n)
    }

    /// Replace the store contents from snapshot `bytes`. All entries are
    /// validated *before* any mutation (mutual compatibility, fit with the
    /// band layout, and — when `expect` is given — the serving config's
    /// `(family, seed, k)`), so a bad snapshot leaves the store untouched;
    /// the swap itself runs under the exclusive gate, so concurrent
    /// requests see either the old store or the fully restored one.
    /// Per-key versions restore with the registers (v1 snapshots: all 0).
    pub fn restore_bytes(
        &self,
        bytes: &[u8],
        expect: Option<(Family, u64, usize)>,
    ) -> anyhow::Result<usize> {
        let entries = codec::decode_store(bytes)?;
        if let Some((first_key, _, first)) = entries.first() {
            for (key, _, sk) in &entries {
                if let Some((family, seed, k)) = expect {
                    anyhow::ensure!(
                        sk.family == family && sk.seed == seed && sk.k() == k,
                        "snapshot entry '{key}' (family '{}', seed {}, k {}) does not match \
                         the serving config (family '{}', seed {seed}, k {k})",
                        sk.family.name(),
                        sk.seed,
                        sk.k(),
                        family.name(),
                    );
                }
                anyhow::ensure!(
                    (self.lsh_params.bands - 1) * self.lsh_params.rows < sk.k(),
                    "snapshot entry '{key}' has k={} but the index needs {}x{} bands",
                    sk.k(),
                    self.lsh_params.bands,
                    self.lsh_params.rows,
                );
                first.check_compatible(sk).map_err(|e| {
                    anyhow::anyhow!("snapshot entries '{first_key}' and '{key}' disagree: {e}")
                })?;
            }
        }
        let n = entries.len();
        let _gate = self.gate.write().expect("store gate");
        self.clear_inner();
        for (key, version, sk) in entries {
            self.upsert_inner(&key, Some(version), sk);
        }
        Ok(n)
    }

    /// Drop every entry and reset the LSH index.
    pub fn clear(&self) {
        let _gate = self.gate.write().expect("store gate");
        self.clear_inner();
    }

    fn clear_inner(&self) {
        for (idx, shard) in self.shards.iter().enumerate() {
            shard.write().expect("store shard lock").clear();
            self.gens[idx].fetch_add(1, Ordering::SeqCst);
        }
        self.delete_gen.fetch_add(1, Ordering::SeqCst);
        *self.lsh.write().expect("store lsh lock") = LshIndex::new(self.lsh_params);
        self.names.write().expect("store names lock").clear();
    }

    /// Stats for the `store_stats` op: size, shard occupancy, index shape,
    /// plus the write/version-drop generations the read-path cache tags
    /// answers with (additive — pre-cache clients ignore them).
    pub fn stats(&self) -> Value {
        let _gate = self.gate.read().expect("store gate");
        let sizes = self.shard_sizes_inner();
        let total: usize = sizes.iter().sum();
        Value::obj(vec![
            ("size", Value::num(total as f64)),
            ("shards", Value::num(sizes.len() as f64)),
            ("shard_min", Value::num(sizes.iter().copied().min().unwrap_or(0) as f64)),
            ("shard_max", Value::num(sizes.iter().copied().max().unwrap_or(0) as f64)),
            (
                "lsh_size",
                Value::num(self.lsh.read().expect("store lsh lock").len() as f64),
            ),
            ("bands", Value::num(self.lsh_params.bands as f64)),
            ("rows", Value::num(self.lsh_params.rows as f64)),
            ("generation", Value::num(self.generations().iter().sum::<u64>() as f64)),
            ("delete_generation", Value::num(self.delete_generation() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fastgm::FastGm;
    use crate::sketch::{Sketcher, SparseVector};
    use crate::util::rng::SplitMix64;

    const K: usize = 64;

    fn store() -> SketchStore {
        SketchStore::new(LshParams::for_threshold(K, 0.5), 4)
    }

    fn sketcher() -> FastGm {
        FastGm::new(K, 42)
    }

    fn random_vec(r: &mut SplitMix64, n: usize) -> SparseVector {
        SparseVector::new(
            (0..n).map(|_| r.next_u64()).collect(),
            (0..n).map(|_| r.next_f64() + 0.1).collect(),
        )
    }

    #[test]
    fn upsert_get_delete_roundtrip() {
        let st = store();
        let f = sketcher();
        let v = SparseVector::new(vec![1, 2, 3], vec![1.0, 2.0, 0.5]);
        assert!(st.is_empty());
        assert_eq!(st.upsert("a", f.sketch(&v)), 1);
        assert_eq!(st.len(), 1);
        assert_eq!(st.lsh_len(), 1);
        assert_eq!(st.get("a").unwrap(), f.sketch(&v));
        assert_eq!(st.get_versioned("a").unwrap().0, 1);
        assert!(st.get("b").is_none());
        assert!(st.delete("a"));
        assert!(!st.delete("a"));
        assert!(st.is_empty());
        assert_eq!(st.lsh_len(), 0);
    }

    #[test]
    fn upsert_replaces_in_store_and_index() {
        let st = store();
        let f = sketcher();
        let v1 = SparseVector::new(vec![1, 2], vec![1.0, 1.0]);
        let v2 = SparseVector::new(vec![8, 9], vec![1.0, 1.0]);
        assert_eq!(st.upsert("a", f.sketch(&v1)), 1);
        assert_eq!(st.upsert("a", f.sketch(&v2)), 2, "versions count writes");
        assert_eq!(st.len(), 1);
        assert_eq!(st.lsh_len(), 1);
        // Probing with v2 finds the replacement at similarity 1.
        let (hits, _) = st.probe_topk(&f.sketch(&v2), 1).unwrap();
        assert_eq!(hits, vec![("a".to_string(), 1.0)]);
    }

    /// The deterministic LWW rule: explicit versions install iff strictly
    /// newer, and versionless upserts continue the per-key sequence.
    #[test]
    fn versioned_puts_are_last_writer_wins() {
        let st = store();
        let f = sketcher();
        let old = f.sketch(&SparseVector::new(vec![1], vec![1.0]));
        let new = f.sketch(&SparseVector::new(vec![2], vec![1.0]));
        assert_eq!(st.put_versioned("a", 5, old.clone()), Some(5));
        // Stale and equal versions are refused, store untouched.
        assert_eq!(st.put_versioned("a", 5, new.clone()), None);
        assert_eq!(st.put_versioned("a", 3, new.clone()), None);
        assert_eq!(st.get_versioned("a").unwrap(), (5, old));
        // A newer version replaces.
        assert_eq!(st.put_versioned("a", 9, new.clone()), Some(9));
        assert_eq!(st.get_versioned("a").unwrap(), (9, new.clone()));
        // Versionless upsert continues after the explicit version.
        assert_eq!(st.upsert("a", new.clone()), 10);
        // Delete drops the version: the next write restarts at 1.
        assert!(st.delete("a"));
        assert_eq!(st.version_of("a"), None);
        assert_eq!(st.upsert("a", new), 1);
    }

    #[test]
    fn keys_page_walks_the_store_in_order() {
        let st = store();
        let f = sketcher();
        for i in 0..10 {
            st.upsert(&format!("doc{i}"), f.sketch(&SparseVector::new(vec![i], vec![1.0])));
        }
        st.upsert("doc3", f.sketch(&SparseVector::new(vec![99], vec![1.0]))); // v2
        // Page through with a cursor of 4.
        let mut seen: Vec<(String, u64)> = Vec::new();
        let mut after: Option<String> = None;
        loop {
            let page = st.keys_page(after.as_deref(), 4);
            if page.is_empty() {
                break;
            }
            assert!(page.len() <= 4);
            after = Some(page.last().unwrap().0.clone());
            seen.extend(page);
        }
        let want: Vec<(String, u64)> =
            (0..10).map(|i| (format!("doc{i}"), if i == 3 { 2 } else { 1 })).collect();
        assert_eq!(seen, want, "pages must cover every key exactly once, sorted");
        // A cursor past the end is an empty page, not an error.
        assert!(st.keys_page(Some("zzz"), 4).is_empty());
    }

    #[test]
    fn probe_and_scan_agree_on_ranking() {
        let st = store();
        let f = sketcher();
        let mut r = SplitMix64::new(5);
        let base = random_vec(&mut r, 30);
        st.upsert("base", f.sketch(&base));
        // Near-duplicate: shares most of base's mass.
        let mut near = base.clone();
        near.weights[0] += 0.05;
        st.upsert("near", f.sketch(&near));
        for i in 0..20 {
            st.upsert(&format!("far{i}"), f.sketch(&random_vec(&mut r, 30)));
        }
        let q = f.sketch(&base);
        let (scan, scan_stats) = st.scan_topk(&q, 2).unwrap();
        let (probe, probe_stats) = st.probe_topk(&q, 2).unwrap();
        assert_eq!(scan[0].0, "base");
        assert_eq!(scan[0].1, 1.0);
        assert_eq!(probe, scan, "probe and scan must agree on the top hits");
        assert!(scan_stats.scanned && !probe_stats.scanned);
        assert_eq!(scan_stats.candidates, 22);
        assert!(
            probe_stats.candidates < 22,
            "probe should be sub-linear: {probe_stats:?}"
        );
        assert_eq!(probe_stats.reranked, probe_stats.candidates);
    }

    /// `merge_keys` must equal merging the individually fetched sketches
    /// (§2.3 union), report versions in input order, and refuse missing
    /// keys instead of estimating over a silently shrunken union.
    #[test]
    fn merge_keys_is_the_union_with_versions_in_input_order() {
        let st = store();
        let f = sketcher();
        let va = SparseVector::new(vec![1, 2, 3], vec![1.0, 0.5, 2.0]);
        let vb = SparseVector::new(vec![3, 4], vec![1.5, 1.0]);
        st.upsert("a", f.sketch(&va));
        st.upsert("b", f.sketch(&vb));
        st.upsert("b", f.sketch(&vb)); // bump b to v2
        let keys = vec!["b".to_string(), "a".to_string()];
        let (merged, versions) = st.merge_keys(&keys).unwrap();
        assert_eq!(versions, vec![2, 1], "versions follow input order");
        let want = st.get("a").unwrap().merge(&st.get("b").unwrap()).unwrap();
        assert_eq!(merged, want);
        // A single key is just that key's sketch.
        let (single, versions) = st.merge_keys(&["a".to_string()]).unwrap();
        assert_eq!(single, st.get("a").unwrap());
        assert_eq!(versions, vec![1]);
        // Duplicate keys are idempotent under union semantics.
        let (dup, _) = st.merge_keys(&["a".to_string(), "a".to_string()]).unwrap();
        assert_eq!(dup, st.get("a").unwrap());
        // Missing keys and empty key sets fail loudly.
        let err = st.merge_keys(&["ghost".to_string()]).unwrap_err().to_string();
        assert!(err.contains("no store entry 'ghost'"), "{err}");
        assert!(st.merge_keys(&[]).is_err());
    }

    #[test]
    fn snapshot_restore_is_bit_identical() {
        let st = store();
        let f = sketcher();
        let mut r = SplitMix64::new(9);
        for i in 0..25 {
            st.upsert(&format!("doc{i}"), f.sketch(&random_vec(&mut r, 12)));
        }
        st.upsert("doc7", f.sketch(&random_vec(&mut r, 12))); // version 2
        let (bytes, n) = st.snapshot_bytes();
        assert_eq!(n, 25);
        let st2 = store();
        st2.upsert("stale", f.sketch(&random_vec(&mut r, 3))); // must be dropped
        let n = st2.restore_bytes(&bytes, None).unwrap();
        assert_eq!(n, 25);
        assert_eq!(st2.len(), 25);
        assert!(st2.get("stale").is_none());
        assert_eq!(st2.lsh_len(), 25);
        assert_eq!(st2.snapshot_bytes().0, bytes, "snapshot of restore must be identical");
        // Versions survive the round trip.
        assert_eq!(st2.version_of("doc7"), Some(2));
        assert_eq!(st2.version_of("doc8"), Some(1));
        // The restored index answers queries like the original.
        let q = f.sketch(&random_vec(&mut r, 12));
        assert_eq!(st.probe_topk(&q, 5).unwrap(), st2.probe_topk(&q, 5).unwrap());
    }

    #[test]
    fn restore_validates_before_mutating() {
        let st = store();
        let f = sketcher();
        st.upsert("keep", f.sketch(&SparseVector::new(vec![1], vec![1.0])));
        // Wrong k for the expected config.
        let other = FastGm::new(32, 42).sketch(&SparseVector::new(vec![1], vec![1.0]));
        let bytes = codec::encode_store(&[("x".into(), 1, other)]);
        let err = st
            .restore_bytes(&bytes, Some((Family::Ordered, 42, K)))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not match"), "{err}");
        // Failed restore left the store untouched.
        assert_eq!(st.len(), 1);
        assert!(st.get("keep").is_some());
        // Corrupt bytes are also clean errors.
        assert!(st.restore_bytes(b"garbage", None).is_err());
        assert_eq!(st.len(), 1);
    }

    /// Restore swaps the store atomically: requests racing a restore see
    /// either the old state or the fully restored one, and the store/index
    /// pair can never diverge (the bug the swap gate exists to prevent —
    /// an upsert interleaved into the clear-and-refill loop used to leave
    /// an LSH entry whose shard-map twin had just been wiped).
    #[test]
    fn restore_is_atomic_under_concurrent_ops() {
        let st = std::sync::Arc::new(store());
        let f = sketcher();
        let mut r = SplitMix64::new(17);
        for i in 0..20 {
            st.upsert(&format!("doc{i}"), f.sketch(&random_vec(&mut r, 8)));
        }
        let (snapshot, _) = st.snapshot_bytes();
        let probe = f.sketch(&random_vec(&mut r, 8));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let st = st.clone();
            let f = sketcher();
            let probe = probe.clone();
            handles.push(std::thread::spawn(move || {
                let mut r = SplitMix64::new(100 + t);
                for i in 0..50 {
                    let key = format!("doc{}", r.next_range(0, 25));
                    if i % 3 == 0 {
                        st.delete(&key);
                    } else {
                        st.upsert(&key, f.sketch(&random_vec(&mut r, 8)));
                    }
                    // Queries racing the restores must never error or see
                    // a half-swapped store larger than both states.
                    let (hits, stats) = st.probe_topk(&probe, 5).unwrap();
                    assert!(hits.len() <= 5);
                    assert!(stats.candidates <= 26);
                }
            }));
        }
        for _ in 0..10 {
            assert_eq!(st.restore_bytes(&snapshot, None).unwrap(), 20);
        }
        for h in handles {
            h.join().unwrap();
        }
        // Whatever interleaving happened, store and index agree exactly.
        assert_eq!(st.len(), st.lsh_len());
        st.probe_topk(&probe, 5).unwrap();
        st.scan_topk(&probe, 5).unwrap();
    }

    /// The cache-tag counters: every install/delete/clear bumps its shard
    /// generation, only version-dropping events bump `delete_gen`, and
    /// `members_match` validates exactly the (key, version) vector —
    /// including the delete + re-upsert case where the raw version matches
    /// again but the registers may differ.
    #[test]
    fn generations_and_members_match_track_writes() {
        let st = store();
        let f = sketcher();
        let sk = |id: u64| f.sketch(&SparseVector::new(vec![id], vec![1.0]));
        assert_eq!(st.generations().iter().sum::<u64>(), 0);
        assert_eq!(st.delete_generation(), 0);
        st.upsert("a", sk(1));
        st.upsert("b", sk(2));
        assert_eq!(st.generations().iter().sum::<u64>(), 2, "installs bump shard gens");
        assert_eq!(st.delete_generation(), 0, "upserts never bump the version-drop counter");

        let tag = vec![("a".to_string(), 1u64), ("b".to_string(), 1u64)];
        let dgen = st.delete_generation();
        assert!(st.members_match(&tag, dgen));
        // A member bumped past its tagged version invalidates.
        st.upsert("a", sk(3));
        assert!(!st.members_match(&tag, dgen));
        let tag2 = vec![("a".to_string(), 2u64), ("b".to_string(), 1u64)];
        assert!(st.members_match(&tag2, dgen));
        // A missing member invalidates.
        assert!(!st.members_match(&[("ghost".to_string(), 1)], dgen));

        // Delete + re-upsert restarts the version sequence at 1 — the raw
        // (key, version) vector would match the pre-delete tag again, but
        // the delete generation catches it.
        let tag_a1 = vec![("a".to_string(), 1u64)];
        let st2 = store();
        st2.upsert("a", sk(1));
        let d0 = st2.delete_generation();
        assert!(st2.members_match(&tag_a1, d0));
        assert!(st2.delete(&"a".to_string()));
        st2.upsert("a", sk(99));
        assert_eq!(st2.version_of("a"), Some(1), "precondition: version restarted");
        assert!(!st2.members_match(&tag_a1, d0), "delete_gen must invalidate the old tag");
        assert!(st2.members_match(&tag_a1, st2.delete_generation()));

        // clear (and therefore restore) bumps both counters.
        let before = (st.generations(), st.delete_generation());
        st.clear();
        assert!(st.delete_generation() > before.1);
        assert!(st.generations().iter().sum::<u64>() > before.0.iter().sum::<u64>());
    }

    #[test]
    fn stats_report_shape_and_occupancy() {
        let st = store();
        let f = sketcher();
        for i in 0..10 {
            st.upsert(&format!("k{i}"), f.sketch(&SparseVector::new(vec![i], vec![1.0])));
        }
        let stats = st.stats();
        assert_eq!(stats.get("size").unwrap().as_f64(), Some(10.0));
        assert_eq!(stats.get("shards").unwrap().as_f64(), Some(4.0));
        assert_eq!(stats.get("lsh_size").unwrap().as_f64(), Some(10.0));
        let params = st.lsh_params();
        assert_eq!(stats.get("bands").unwrap().as_f64(), Some(params.bands as f64));
        assert_eq!(stats.get("rows").unwrap().as_f64(), Some(params.rows as f64));
    }
}
