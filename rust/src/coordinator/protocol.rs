//! Wire protocol: newline-delimited JSON objects with an `"op"` tag.
//!
//! Every request/response round-trips through [`crate::util::json`]; the
//! encoding tests below lock the format (it is also what
//! `examples/serve_e2e.rs` and the Python-free CLI client speak).

use crate::sketch::codec;
use crate::sketch::{GumbelMaxSketch, SparseVector};
use crate::util::json::{self, Value};

/// Wire protocol version, answered by the `hello` op. Bumped whenever an
/// existing encoding changes shape (adding a new op does not bump it —
/// unknown ops already fail loudly).
///
/// v2: `upsert` gained the optional, semantics-bearing `version` field.
/// A v1 node would silently IGNORE it (its decoder drops unknown fields)
/// and assign its own version, corrupting last-writer-wins ordering —
/// exactly the class of skew the bump exists to catch: the cluster
/// handshake refuses to form across protocol versions, loudly.
///
/// v3: the query-engine ops `sample` / `partition` and the `samples`
/// response. New ops normally ride without a bump, but these are
/// *scattered by cluster clients*: a mixed cluster where some nodes
/// cannot serve sampling would fail per-query and per-replica instead of
/// at connect. Advertising v3 in `hello` lets the handshake refuse the
/// skew up front, same as v2 did for versioned writes.
///
/// v4: the binary blob ops `sketch_fetch_bin` / `store_put_bin` /
/// `stream_merge_bin` and the `sketch_blob_bin` response — the framed
/// transport's raw-`sketch::codec` data plane. Same rationale as v3:
/// framed cluster clients scatter these to every replica on the hot
/// gather/repair paths, so a mixed cluster where some nodes cannot serve
/// them must refuse at connect, not fail per-blob mid-repair.
pub const PROTOCOL_VERSION: u64 = 4;

/// Which server-side collection a `sketch_fetch` reads from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchSource {
    /// The keyed similarity store (`upsert` entries).
    Store,
    /// The named sketch registry (`sketch` / `merge` results).
    Registry,
    /// A live stream state's current sketch (`push` accumulations).
    Stream,
}

impl SketchSource {
    pub fn name(self) -> &'static str {
        match self {
            SketchSource::Store => "store",
            SketchSource::Registry => "registry",
            SketchSource::Stream => "stream",
        }
    }

    pub fn from_name(s: &str) -> anyhow::Result<SketchSource> {
        Ok(match s {
            "store" => SketchSource::Store,
            "registry" => SketchSource::Registry,
            "stream" => SketchSource::Stream,
            other => anyhow::bail!(
                "unknown sketch_fetch source '{other}' (known: store, registry, stream)"
            ),
        })
    }
}

/// What a query-engine op (`sample` / `partition`) reads its sketch from:
/// one or more keyed-store entries (union-merged via §2.3 when several —
/// exact, no raw-vector access) or a live stream state. On the wire this
/// is the `key` | `keys` | `stream` field trio, exactly one present.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryTarget {
    /// Keyed-store entries; two or more are merged into their exact union
    /// sketch before the query runs.
    Keys(Vec<String>),
    /// A named Stream-FastGM state's current sketch.
    Stream(String),
}

impl QueryTarget {
    pub fn key(k: impl Into<String>) -> QueryTarget {
        QueryTarget::Keys(vec![k.into()])
    }

    fn push_json(&self, fields: &mut Vec<(&str, Value)>) {
        match self {
            QueryTarget::Keys(keys) if keys.len() == 1 => {
                fields.push(("key", Value::str(keys[0].clone())));
            }
            QueryTarget::Keys(keys) => fields.push((
                "keys",
                Value::Arr(keys.iter().map(|k| Value::str(k.clone())).collect()),
            )),
            QueryTarget::Stream(s) => fields.push(("stream", Value::str(s.clone()))),
        }
    }

    fn from_json(v: &Value) -> anyhow::Result<QueryTarget> {
        let (key, keys, stream) = (v.get("key"), v.get("keys"), v.get("stream"));
        let present = [&key, &keys, &stream].iter().filter(|f| f.is_some()).count();
        anyhow::ensure!(
            present == 1,
            "exactly one of 'key', 'keys', 'stream' must be given (got {present})"
        );
        Ok(if let Some(k) = key {
            QueryTarget::Keys(vec![k
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("field 'key' not a string"))?
                .to_string()])
        } else if let Some(ks) = keys {
            QueryTarget::Keys(
                ks.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("field 'keys' not an array"))?
                    .iter()
                    .map(|k| {
                        k.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow::anyhow!("bad key in 'keys'"))
                    })
                    .collect::<anyhow::Result<_>>()?,
            )
        } else {
            QueryTarget::Stream(
                stream
                    .unwrap()
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("field 'stream' not a string"))?
                    .to_string(),
            )
        })
    }
}

/// The `hello` handshake reply: enough for a cluster client to verify it is
/// talking to a compatible node (protocol + sketch config) and to identify
/// the node across restarts (`node` id; `epoch` counts snapshot restores).
#[derive(Debug, Clone, PartialEq)]
pub struct HelloInfo {
    pub protocol: u64,
    pub node: String,
    pub epoch: u64,
    pub k: usize,
    pub seed: u64,
    /// The node's default sketch algorithm (what `upsert`/`topk` probe with).
    pub algo: String,
    /// Every engine-registry algorithm the node serves.
    pub algos: Vec<String>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Sketch a sparse vector and store it. `algo` selects the engine-
    /// registry algorithm by name (`fastgm`, `fastgm-c`, `sharded`,
    /// `stream`, `pminhash`, `lemiesz`, `icws`, `bagminhash`, `minhash`);
    /// omitted means the coordinator's configured default (`sketch.algo`,
    /// itself defaulting to FastGM). Unknown names produce an error
    /// response listing the registry.
    Sketch { name: String, vector: SparseVector, algo: Option<String> },
    /// Sketch a dense row — router may batch it onto the accelerator
    /// (Direct family).
    SketchDense { name: String, weights: Vec<f64> },
    /// Fetch a stored sketch.
    GetSketch { name: String },
    /// Push stream elements into a named Stream-FastGM state.
    Push { stream: String, items: Vec<(u64, f64)> },
    /// Weighted cardinality estimate of a stream.
    Cardinality { stream: String },
    /// Probability-Jaccard estimate between two stored sketches.
    Jaccard { a: String, b: String },
    /// Weighted-Jaccard estimate via cardinality algebra.
    WeightedJaccard { a: String, b: String },
    /// Merge stored sketches (distributed sites, §2.3) into `out`.
    Merge { names: Vec<String>, out: String },
    /// Insert a stored sketch into the LSH index.
    LshInsert { name: String },
    /// Query the LSH index with a fresh vector.
    LshQuery { vector: SparseVector, limit: usize },
    /// Sketch a vector (default algo) and upsert it into the keyed store
    /// under `key`, keeping the store's LSH index in sync. `version` is
    /// the optional explicit write version: `None` lets the store assign
    /// the next per-key version (`previous + 1`); `Some(v)` installs at
    /// exactly `v` if strictly newer than the held copy and is otherwise
    /// a refused-as-stale ack — the deterministic last-writer-wins rule
    /// replicated writes converge by.
    Upsert { key: String, vector: SparseVector, version: Option<u64> },
    /// Remove `key` from the keyed store and its LSH index (idempotent).
    Delete { key: String },
    /// One page of the keyed store's `(key, version)` range walk: up to
    /// `limit` pairs with `key > after`, sorted — the anti-entropy repair
    /// path diffs replica states range by range through this.
    StoreKeys { after: Option<String>, limit: usize },
    /// Install one codec blob (`sketch::codec` hex, key + version inside)
    /// into the keyed store under last-writer-wins: strictly newer
    /// versions replace, stale ones are acked as kept — how repair
    /// streams a healthy replica's entries onto a rejoined/cold node.
    StorePut { data: String },
    /// Merge one codec blob into the named live stream state (creating it
    /// if absent). Merging — never overwriting — is the §2.3-safe repair
    /// for streams: local pushes are kept, missed ones absorbed.
    StreamMerge { stream: String, data: String },
    /// Top-`limit` most similar store entries to a fresh vector:
    /// band-probe + full-sketch re-rank (or a brute scan on small stores).
    TopK { vector: SparseVector, limit: usize },
    /// Draw `n` element ids ∝ weight from the target's sketch (register-
    /// as-sample; multiple keys sample the exact §2.3 union). `seed` makes
    /// the draw reproducible: same `(state, n, seed)` → same ids on every
    /// node and transport.
    Sample { target: QueryTarget, n: usize, seed: u64 },
    /// Estimate the target's total weight `Z = Σ w_i` (partition function)
    /// from its `y` registers — `(k-1)/Σy`, Balog-style.
    Partition { target: QueryTarget },
    /// Keyed-store statistics (size, shard occupancy, index shape).
    StoreStats,
    /// Freeze the keyed store to `path` in the versioned binary snapshot
    /// format (`sketch::codec`).
    Snapshot { path: String },
    /// Replace the keyed store contents from the snapshot at `path`.
    Restore { path: String },
    /// Version/identity handshake: the server answers protocol version,
    /// node id, state epoch and supported algorithms ([`HelloInfo`]).
    Hello,
    /// Fetch one sketch as a codec-encoded blob (`sketch::codec`, hex) —
    /// the cluster gather path's transfer op (§2.3 sketches move between
    /// sites in the same versioned, checksummed format they persist in).
    SketchFetch { name: String, source: SketchSource },
    /// Metrics snapshot.
    Metrics,
    Ping,
    /// [`Request::StorePut`] with the codec blob as **raw bytes** — the
    /// framed transport's binary data plane (no hex, written/read without
    /// re-buffering). On the JSON wire the bytes surface as hex, so the
    /// op stays speakable (and golden-testable) on both transports.
    StorePutBin { data: Vec<u8> },
    /// [`Request::StreamMerge`] with a raw-byte codec blob (see
    /// [`Request::StorePutBin`] for the transport encoding rule).
    StreamMergeBin { stream: String, data: Vec<u8> },
    /// [`Request::SketchFetch`] answered with [`Response::SketchBlobBin`]
    /// (raw codec bytes) instead of a hex [`Response::SketchBlob`].
    SketchFetchBin { name: String, source: SketchSource },
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Sketch { name: String, sketch: GumbelMaxSketch },
    Ack { info: String },
    Estimate { value: f64 },
    TopK { hits: Vec<(String, f64)> },
    MetricsDump { snapshot: Value },
    /// Keyed-store statistics (the `store_stats` op's reply).
    Stats { stats: Value },
    /// One `(key, version)` page of the store's range walk (`store_keys`).
    Keys { keys: Vec<(String, u64)> },
    /// The `hello` handshake reply.
    Hello { info: HelloInfo },
    /// One codec-encoded sketch (`sketch_fetch`'s reply); `data` is the hex
    /// blob [`crate::sketch::codec::decode_sketch_hex`] reads.
    SketchBlob { name: String, data: String },
    /// The drawn element ids (`sample`'s reply), in draw order.
    Samples { ids: Vec<u64> },
    Error { message: String },
    Pong,
    /// One codec-encoded sketch as **raw bytes** (`sketch_fetch_bin`'s
    /// reply). The framed transport writes `data` without re-encoding it;
    /// the JSON wire carries it as hex (see [`Request::StorePutBin`]).
    SketchBlobBin { name: String, data: Vec<u8> },
}

fn vector_to_json(v: &SparseVector) -> Value {
    Value::obj(vec![
        ("ids", Value::arr_u64(&v.ids)),
        ("weights", Value::arr_f64(&v.weights)),
    ])
}

fn vector_from_json(v: &Value) -> anyhow::Result<SparseVector> {
    let ids = v
        .req("ids")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("ids not an array"))?
        .iter()
        .map(|x| x.as_u64_lossless().ok_or_else(|| anyhow::anyhow!("bad id")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let weights = v
        .req("weights")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("weights not an array"))?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("bad weight")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    anyhow::ensure!(ids.len() == weights.len(), "ids/weights length mismatch");
    check_weights(&weights)?;
    Ok(SparseVector::new(ids, weights))
}

/// Ingress guard shared with the framed decode path: Gumbel-Max races are
/// only defined for non-negative finite weights — a NaN/±inf/negative
/// entry would silently poison every register it touches, so reject it
/// loudly at the wire, naming the offending index.
pub(crate) fn check_weights(weights: &[f64]) -> anyhow::Result<()> {
    for (i, &w) in weights.iter().enumerate() {
        anyhow::ensure!(
            w.is_finite() && w >= 0.0,
            "vector weight at index {i} is {w}: Gumbel-Max requires non-negative finite weights"
        );
    }
    Ok(())
}

impl Request {
    pub fn to_json(&self) -> Value {
        match self {
            Request::Sketch { name, vector, algo } => {
                let mut fields = vec![
                    ("op", Value::str("sketch")),
                    ("name", Value::str(name.clone())),
                    ("vector", vector_to_json(vector)),
                ];
                if let Some(a) = algo {
                    fields.push(("algo", Value::str(a.clone())));
                }
                Value::obj(fields)
            }
            Request::SketchDense { name, weights } => Value::obj(vec![
                ("op", Value::str("sketch_dense")),
                ("name", Value::str(name.clone())),
                ("weights", Value::arr_f64(weights)),
            ]),
            Request::GetSketch { name } => Value::obj(vec![
                ("op", Value::str("get_sketch")),
                ("name", Value::str(name.clone())),
            ]),
            Request::Push { stream, items } => Value::obj(vec![
                ("op", Value::str("push")),
                ("stream", Value::str(stream.clone())),
                (
                    "items",
                    Value::Arr(
                        items
                            .iter()
                            .map(|(id, w)| {
                                Value::Arr(vec![Value::u64(*id), Value::num(*w)])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Request::Cardinality { stream } => Value::obj(vec![
                ("op", Value::str("cardinality")),
                ("stream", Value::str(stream.clone())),
            ]),
            Request::Jaccard { a, b } => Value::obj(vec![
                ("op", Value::str("jaccard")),
                ("a", Value::str(a.clone())),
                ("b", Value::str(b.clone())),
            ]),
            Request::WeightedJaccard { a, b } => Value::obj(vec![
                ("op", Value::str("weighted_jaccard")),
                ("a", Value::str(a.clone())),
                ("b", Value::str(b.clone())),
            ]),
            Request::Merge { names, out } => Value::obj(vec![
                ("op", Value::str("merge")),
                (
                    "names",
                    Value::Arr(names.iter().map(|n| Value::str(n.clone())).collect()),
                ),
                ("out", Value::str(out.clone())),
            ]),
            Request::LshInsert { name } => Value::obj(vec![
                ("op", Value::str("lsh_insert")),
                ("name", Value::str(name.clone())),
            ]),
            Request::LshQuery { vector, limit } => Value::obj(vec![
                ("op", Value::str("lsh_query")),
                ("vector", vector_to_json(vector)),
                ("limit", Value::num(*limit as f64)),
            ]),
            Request::Upsert { key, vector, version } => {
                let mut fields = vec![
                    ("op", Value::str("upsert")),
                    ("key", Value::str(key.clone())),
                    ("vector", vector_to_json(vector)),
                ];
                if let Some(v) = version {
                    fields.push(("version", Value::u64(*v)));
                }
                Value::obj(fields)
            }
            Request::Delete { key } => Value::obj(vec![
                ("op", Value::str("delete")),
                ("key", Value::str(key.clone())),
            ]),
            Request::StoreKeys { after, limit } => {
                let mut fields = vec![("op", Value::str("store_keys"))];
                if let Some(a) = after {
                    fields.push(("after", Value::str(a.clone())));
                }
                fields.push(("limit", Value::num(*limit as f64)));
                Value::obj(fields)
            }
            Request::StorePut { data } => Value::obj(vec![
                ("op", Value::str("store_put")),
                ("data", Value::str(data.clone())),
            ]),
            Request::StreamMerge { stream, data } => Value::obj(vec![
                ("op", Value::str("stream_merge")),
                ("stream", Value::str(stream.clone())),
                ("data", Value::str(data.clone())),
            ]),
            Request::TopK { vector, limit } => Value::obj(vec![
                ("op", Value::str("topk")),
                ("vector", vector_to_json(vector)),
                ("limit", Value::num(*limit as f64)),
            ]),
            Request::Sample { target, n, seed } => {
                let mut fields = vec![("op", Value::str("sample"))];
                target.push_json(&mut fields);
                fields.push(("n", Value::num(*n as f64)));
                fields.push(("seed", Value::u64(*seed)));
                Value::obj(fields)
            }
            Request::Partition { target } => {
                let mut fields = vec![("op", Value::str("partition"))];
                target.push_json(&mut fields);
                Value::obj(fields)
            }
            Request::StoreStats => Value::obj(vec![("op", Value::str("store_stats"))]),
            Request::Snapshot { path } => Value::obj(vec![
                ("op", Value::str("snapshot")),
                ("path", Value::str(path.clone())),
            ]),
            Request::Restore { path } => Value::obj(vec![
                ("op", Value::str("restore")),
                ("path", Value::str(path.clone())),
            ]),
            Request::Hello => Value::obj(vec![("op", Value::str("hello"))]),
            Request::SketchFetch { name, source } => Value::obj(vec![
                ("op", Value::str("sketch_fetch")),
                ("name", Value::str(name.clone())),
                ("source", Value::str(source.name())),
            ]),
            Request::Metrics => Value::obj(vec![("op", Value::str("metrics"))]),
            Request::Ping => Value::obj(vec![("op", Value::str("ping"))]),
            Request::StorePutBin { data } => Value::obj(vec![
                ("op", Value::str("store_put_bin")),
                ("data", Value::str(codec::to_hex(data))),
            ]),
            Request::StreamMergeBin { stream, data } => Value::obj(vec![
                ("op", Value::str("stream_merge_bin")),
                ("stream", Value::str(stream.clone())),
                ("data", Value::str(codec::to_hex(data))),
            ]),
            Request::SketchFetchBin { name, source } => Value::obj(vec![
                ("op", Value::str("sketch_fetch_bin")),
                ("name", Value::str(name.clone())),
                ("source", Value::str(source.name())),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Request> {
        Ok(match v.req_str("op")? {
            "sketch" => Request::Sketch {
                name: v.req_str("name")?.to_string(),
                vector: vector_from_json(v.req("vector")?)?,
                algo: match v.get("algo") {
                    None => None,
                    Some(a) => Some(
                        a.as_str()
                            .ok_or_else(|| anyhow::anyhow!("field 'algo' not a string"))?
                            .to_string(),
                    ),
                },
            },
            "sketch_dense" => Request::SketchDense {
                name: v.req_str("name")?.to_string(),
                weights: v
                    .req("weights")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("weights not an array"))?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("bad weight")))
                    .collect::<anyhow::Result<_>>()?,
            },
            "get_sketch" => Request::GetSketch { name: v.req_str("name")?.to_string() },
            "push" => Request::Push {
                stream: v.req_str("stream")?.to_string(),
                items: v
                    .req("items")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("items not an array"))?
                    .iter()
                    .map(|pair| {
                        let id = pair
                            .idx(0)
                            .and_then(|x| x.as_u64_lossless())
                            .ok_or_else(|| anyhow::anyhow!("bad item id"))?;
                        let w = pair
                            .idx(1)
                            .and_then(|x| x.as_f64())
                            .ok_or_else(|| anyhow::anyhow!("bad item weight"))?;
                        Ok((id, w))
                    })
                    .collect::<anyhow::Result<_>>()?,
            },
            "cardinality" => Request::Cardinality { stream: v.req_str("stream")?.to_string() },
            "jaccard" => Request::Jaccard {
                a: v.req_str("a")?.to_string(),
                b: v.req_str("b")?.to_string(),
            },
            "weighted_jaccard" => Request::WeightedJaccard {
                a: v.req_str("a")?.to_string(),
                b: v.req_str("b")?.to_string(),
            },
            "merge" => Request::Merge {
                names: v
                    .req("names")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("names not an array"))?
                    .iter()
                    .map(|x| {
                        x.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow::anyhow!("bad name"))
                    })
                    .collect::<anyhow::Result<_>>()?,
                out: v.req_str("out")?.to_string(),
            },
            "lsh_insert" => Request::LshInsert { name: v.req_str("name")?.to_string() },
            "lsh_query" => Request::LshQuery {
                vector: vector_from_json(v.req("vector")?)?,
                limit: v.req_usize("limit")?,
            },
            "upsert" => Request::Upsert {
                key: v.req_str("key")?.to_string(),
                vector: vector_from_json(v.req("vector")?)?,
                version: match v.get("version") {
                    None => None,
                    Some(x) => Some(
                        x.as_u64_lossless()
                            .ok_or_else(|| anyhow::anyhow!("field 'version' not a u64"))?,
                    ),
                },
            },
            "delete" => Request::Delete { key: v.req_str("key")?.to_string() },
            "store_keys" => Request::StoreKeys {
                after: match v.get("after") {
                    None => None,
                    Some(a) => Some(
                        a.as_str()
                            .ok_or_else(|| anyhow::anyhow!("field 'after' not a string"))?
                            .to_string(),
                    ),
                },
                limit: v.req_usize("limit")?,
            },
            "store_put" => Request::StorePut { data: v.req_str("data")?.to_string() },
            "stream_merge" => Request::StreamMerge {
                stream: v.req_str("stream")?.to_string(),
                data: v.req_str("data")?.to_string(),
            },
            "topk" => Request::TopK {
                vector: vector_from_json(v.req("vector")?)?,
                limit: v.req_usize("limit")?,
            },
            "sample" => Request::Sample {
                target: QueryTarget::from_json(v)?,
                n: v.req_usize("n")?,
                seed: v
                    .req("seed")?
                    .as_u64_lossless()
                    .ok_or_else(|| anyhow::anyhow!("field 'seed' not a u64"))?,
            },
            "partition" => Request::Partition { target: QueryTarget::from_json(v)? },
            "store_stats" => Request::StoreStats,
            "snapshot" => Request::Snapshot { path: v.req_str("path")?.to_string() },
            "restore" => Request::Restore { path: v.req_str("path")?.to_string() },
            "hello" => Request::Hello,
            "sketch_fetch" => Request::SketchFetch {
                name: v.req_str("name")?.to_string(),
                // Optional on the wire (raw-JSON CLI convenience); the
                // keyed store is the overwhelmingly common source.
                source: match v.get("source") {
                    None => SketchSource::Store,
                    Some(s) => SketchSource::from_name(
                        s.as_str()
                            .ok_or_else(|| anyhow::anyhow!("field 'source' not a string"))?,
                    )?,
                },
            },
            "metrics" => Request::Metrics,
            "ping" => Request::Ping,
            "store_put_bin" => Request::StorePutBin {
                data: codec::from_hex(v.req_str("data")?)?,
            },
            "stream_merge_bin" => Request::StreamMergeBin {
                stream: v.req_str("stream")?.to_string(),
                data: codec::from_hex(v.req_str("data")?)?,
            },
            "sketch_fetch_bin" => Request::SketchFetchBin {
                name: v.req_str("name")?.to_string(),
                source: SketchSource::from_name(v.req_str("source")?)?,
            },
            other => anyhow::bail!("unknown op '{other}'"),
        })
    }

    /// Op tag (metrics label).
    pub fn op(&self) -> &'static str {
        match self {
            Request::Sketch { .. } => "sketch",
            Request::SketchDense { .. } => "sketch_dense",
            Request::GetSketch { .. } => "get_sketch",
            Request::Push { .. } => "push",
            Request::Cardinality { .. } => "cardinality",
            Request::Jaccard { .. } => "jaccard",
            Request::WeightedJaccard { .. } => "weighted_jaccard",
            Request::Merge { .. } => "merge",
            Request::LshInsert { .. } => "lsh_insert",
            Request::LshQuery { .. } => "lsh_query",
            Request::Upsert { .. } => "upsert",
            Request::Delete { .. } => "delete",
            Request::StoreKeys { .. } => "store_keys",
            Request::StorePut { .. } => "store_put",
            Request::StreamMerge { .. } => "stream_merge",
            Request::TopK { .. } => "topk",
            Request::Sample { .. } => "sample",
            Request::Partition { .. } => "partition",
            Request::StoreStats => "store_stats",
            Request::Snapshot { .. } => "snapshot",
            Request::Restore { .. } => "restore",
            Request::Hello => "hello",
            Request::SketchFetch { .. } => "sketch_fetch",
            Request::Metrics => "metrics",
            Request::Ping => "ping",
            Request::StorePutBin { .. } => "store_put_bin",
            Request::StreamMergeBin { .. } => "stream_merge_bin",
            Request::SketchFetchBin { .. } => "sketch_fetch_bin",
        }
    }
}

impl Response {
    pub fn to_json(&self) -> Value {
        match self {
            Response::Sketch { name, sketch } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("sketch")),
                ("name", Value::str(name.clone())),
                ("sketch", sketch.to_json()),
            ]),
            Response::Ack { info } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("ack")),
                ("info", Value::str(info.clone())),
            ]),
            Response::Estimate { value } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("estimate")),
                ("value", Value::num(*value)),
            ]),
            Response::TopK { hits } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("topk")),
                (
                    "hits",
                    Value::Arr(
                        hits.iter()
                            .map(|(n, s)| {
                                Value::Arr(vec![Value::str(n.clone()), Value::num(*s)])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::MetricsDump { snapshot } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("metrics")),
                ("snapshot", snapshot.clone()),
            ]),
            Response::Stats { stats } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("stats")),
                ("stats", stats.clone()),
            ]),
            Response::Keys { keys } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("keys")),
                (
                    "keys",
                    Value::Arr(
                        keys.iter()
                            .map(|(k, v)| {
                                Value::Arr(vec![Value::str(k.clone()), Value::u64(*v)])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Hello { info } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("hello")),
                ("protocol", Value::num(info.protocol as f64)),
                ("node", Value::str(info.node.clone())),
                ("epoch", Value::num(info.epoch as f64)),
                ("k", Value::num(info.k as f64)),
                ("seed", Value::u64(info.seed)),
                ("algo", Value::str(info.algo.clone())),
                (
                    "algos",
                    Value::Arr(info.algos.iter().map(|a| Value::str(a.clone())).collect()),
                ),
            ]),
            Response::SketchBlob { name, data } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("sketch_blob")),
                ("name", Value::str(name.clone())),
                ("data", Value::str(data.clone())),
            ]),
            Response::Samples { ids } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("samples")),
                // arr_u64 keeps >2^53 element ids lossless (string form).
                ("ids", Value::arr_u64(ids)),
            ]),
            Response::Error { message } => Value::obj(vec![
                ("ok", Value::Bool(false)),
                ("type", Value::str("error")),
                ("message", Value::str(message.clone())),
            ]),
            Response::Pong => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("pong")),
            ]),
            Response::SketchBlobBin { name, data } => Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("type", Value::str("sketch_blob_bin")),
                ("name", Value::str(name.clone())),
                ("data", Value::str(codec::to_hex(data))),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Response> {
        Ok(match v.req_str("type")? {
            "sketch" => Response::Sketch {
                name: v.req_str("name")?.to_string(),
                sketch: GumbelMaxSketch::from_json(v.req("sketch")?)?,
            },
            "ack" => Response::Ack { info: v.req_str("info")?.to_string() },
            "estimate" => Response::Estimate { value: v.req_f64("value")? },
            "topk" => Response::TopK {
                hits: v
                    .req("hits")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("hits not an array"))?
                    .iter()
                    .map(|pair| {
                        let n = pair
                            .idx(0)
                            .and_then(|x| x.as_str())
                            .ok_or_else(|| anyhow::anyhow!("bad hit name"))?;
                        let s = pair
                            .idx(1)
                            .and_then(|x| x.as_f64())
                            .ok_or_else(|| anyhow::anyhow!("bad hit score"))?;
                        Ok((n.to_string(), s))
                    })
                    .collect::<anyhow::Result<_>>()?,
            },
            "metrics" => Response::MetricsDump { snapshot: v.req("snapshot")?.clone() },
            "stats" => Response::Stats { stats: v.req("stats")?.clone() },
            "keys" => Response::Keys {
                keys: v
                    .req("keys")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("keys not an array"))?
                    .iter()
                    .map(|pair| {
                        let k = pair
                            .idx(0)
                            .and_then(|x| x.as_str())
                            .ok_or_else(|| anyhow::anyhow!("bad key name"))?;
                        let ver = pair
                            .idx(1)
                            .and_then(|x| x.as_u64_lossless())
                            .ok_or_else(|| anyhow::anyhow!("bad key version"))?;
                        Ok((k.to_string(), ver))
                    })
                    .collect::<anyhow::Result<_>>()?,
            },
            "hello" => Response::Hello {
                info: HelloInfo {
                    protocol: v
                        .req("protocol")?
                        .as_u64_lossless()
                        .ok_or_else(|| anyhow::anyhow!("bad protocol version"))?,
                    node: v.req_str("node")?.to_string(),
                    epoch: v
                        .req("epoch")?
                        .as_u64_lossless()
                        .ok_or_else(|| anyhow::anyhow!("bad epoch"))?,
                    k: v.req_usize("k")?,
                    seed: v
                        .req("seed")?
                        .as_u64_lossless()
                        .ok_or_else(|| anyhow::anyhow!("bad seed"))?,
                    algo: v.req_str("algo")?.to_string(),
                    algos: v
                        .req("algos")?
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("algos not an array"))?
                        .iter()
                        .map(|a| {
                            a.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| anyhow::anyhow!("bad algo name"))
                        })
                        .collect::<anyhow::Result<_>>()?,
                },
            },
            "sketch_blob" => Response::SketchBlob {
                name: v.req_str("name")?.to_string(),
                data: v.req_str("data")?.to_string(),
            },
            "sketch_blob_bin" => Response::SketchBlobBin {
                name: v.req_str("name")?.to_string(),
                data: codec::from_hex(v.req_str("data")?)?,
            },
            "samples" => Response::Samples {
                ids: v
                    .req("ids")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("ids not an array"))?
                    .iter()
                    .map(|x| {
                        x.as_u64_lossless().ok_or_else(|| anyhow::anyhow!("bad sample id"))
                    })
                    .collect::<anyhow::Result<_>>()?,
            },
            "error" => Response::Error { message: v.req_str("message")?.to_string() },
            "pong" => Response::Pong,
            other => anyhow::bail!("unknown response type '{other}'"),
        })
    }

    pub fn err(msg: impl std::fmt::Display) -> Response {
        Response::Error { message: msg.to_string() }
    }
}

/// Encode as one wire line.
pub fn encode_line(v: &Value) -> String {
    format!("{v}\n")
}

pub fn decode_request(line: &str) -> anyhow::Result<Request> {
    Request::from_json(&json::parse(line.trim())?)
}

pub fn decode_response(line: &str) -> anyhow::Result<Response> {
    Response::from_json(&json::parse(line.trim())?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Family;

    fn roundtrip_req(r: Request) {
        let line = encode_line(&r.to_json());
        let back = decode_request(&line).unwrap();
        assert_eq!(r, back, "request roundtrip failed for {line}");
    }

    fn roundtrip_resp(r: Response) {
        let line = encode_line(&r.to_json());
        let back = decode_response(&line).unwrap();
        assert_eq!(r, back, "response roundtrip failed for {line}");
    }

    #[test]
    fn all_requests_roundtrip() {
        let v = SparseVector::new(vec![1, 5], vec![0.5, 2.0]);
        roundtrip_req(Request::Sketch { name: "doc1".into(), vector: v.clone(), algo: None });
        roundtrip_req(Request::Sketch {
            name: "doc1".into(),
            vector: v.clone(),
            algo: Some("pminhash".into()),
        });
        roundtrip_req(Request::SketchDense { name: "d".into(), weights: vec![0.0, 1.5] });
        roundtrip_req(Request::GetSketch { name: "doc1".into() });
        roundtrip_req(Request::Push { stream: "s".into(), items: vec![(3, 0.5), (9, 1.0)] });
        roundtrip_req(Request::Cardinality { stream: "s".into() });
        roundtrip_req(Request::Jaccard { a: "x".into(), b: "y".into() });
        roundtrip_req(Request::WeightedJaccard { a: "x".into(), b: "y".into() });
        roundtrip_req(Request::Merge { names: vec!["a".into(), "b".into()], out: "u".into() });
        roundtrip_req(Request::LshInsert { name: "doc1".into() });
        roundtrip_req(Request::LshQuery { vector: v.clone(), limit: 10 });
        roundtrip_req(Request::Upsert { key: "doc1".into(), vector: v.clone(), version: None });
        roundtrip_req(Request::Upsert {
            key: "doc1".into(),
            vector: v.clone(),
            version: Some(u64::MAX - 5), // lossless through the string path
        });
        roundtrip_req(Request::Delete { key: "doc1".into() });
        roundtrip_req(Request::StoreKeys { after: None, limit: 100 });
        roundtrip_req(Request::StoreKeys { after: Some("doc1".into()), limit: 64 });
        roundtrip_req(Request::StorePut { data: "46474d53".into() });
        roundtrip_req(Request::StreamMerge { stream: "s".into(), data: "46474d53".into() });
        roundtrip_req(Request::TopK { vector: v, limit: 5 });
        roundtrip_req(Request::Sample { target: QueryTarget::key("doc1"), n: 8, seed: 7 });
        roundtrip_req(Request::Sample {
            target: QueryTarget::Keys(vec!["doc1".into(), "doc2".into()]),
            n: 3,
            seed: u64::MAX, // lossless through the string path
        });
        roundtrip_req(Request::Sample {
            target: QueryTarget::Stream("pkts".into()),
            n: 1,
            seed: 0,
        });
        roundtrip_req(Request::Partition { target: QueryTarget::key("doc1") });
        roundtrip_req(Request::Partition {
            target: QueryTarget::Keys(vec!["a".into(), "b".into()]),
        });
        roundtrip_req(Request::Partition { target: QueryTarget::Stream("pkts".into()) });
        roundtrip_req(Request::StoreStats);
        roundtrip_req(Request::Snapshot { path: "/tmp/fgm.snap".into() });
        roundtrip_req(Request::Restore { path: "/tmp/fgm.snap".into() });
        roundtrip_req(Request::Hello);
        for source in [SketchSource::Store, SketchSource::Registry, SketchSource::Stream] {
            roundtrip_req(Request::SketchFetch { name: "doc1".into(), source });
        }
        roundtrip_req(Request::Metrics);
        roundtrip_req(Request::Ping);
        roundtrip_req(Request::StorePutBin { data: b"FGMS\x02\x00".to_vec() });
        roundtrip_req(Request::StreamMergeBin {
            stream: "s".into(),
            data: vec![0x46, 0x47, 0x4d, 0x53, 0xff, 0x00],
        });
        for source in [SketchSource::Store, SketchSource::Registry, SketchSource::Stream] {
            roundtrip_req(Request::SketchFetchBin { name: "doc1".into(), source });
        }
    }

    #[test]
    fn all_responses_roundtrip() {
        let mut sk = GumbelMaxSketch::empty(Family::Ordered, 7, 4);
        sk.y[2] = 0.125;
        sk.s[2] = 42;
        roundtrip_resp(Response::Sketch { name: "doc1".into(), sketch: sk });
        roundtrip_resp(Response::Ack { info: "stored".into() });
        roundtrip_resp(Response::Estimate { value: 3.5 });
        roundtrip_resp(Response::TopK { hits: vec![("a".into(), 0.9), ("b".into(), 0.5)] });
        roundtrip_resp(Response::Stats {
            stats: Value::obj(vec![
                ("size", Value::num(3.0)),
                ("shards", Value::num(8.0)),
            ]),
        });
        roundtrip_resp(Response::Keys {
            keys: vec![("doc1".into(), 3), ("doc2".into(), u64::MAX - 1)],
        });
        roundtrip_resp(Response::Keys { keys: vec![] });
        roundtrip_resp(Response::Error { message: "nope".into() });
        roundtrip_resp(Response::Hello {
            info: HelloInfo {
                protocol: PROTOCOL_VERSION,
                node: "node-0".into(),
                epoch: 2,
                k: 256,
                seed: u64::MAX, // survives via the lossless string encoding
                algo: "fastgm".into(),
                algos: vec!["fastgm".into(), "pminhash".into()],
            },
        });
        roundtrip_resp(Response::SketchBlob { name: "doc1".into(), data: "46474d53".into() });
        roundtrip_resp(Response::SketchBlobBin {
            name: "doc1".into(),
            data: b"FGMS\x02\x00\x00".to_vec(),
        });
        roundtrip_resp(Response::Samples { ids: vec![3, 17, 3, u64::MAX - 2] });
        roundtrip_resp(Response::Samples { ids: vec![] });
        roundtrip_resp(Response::Pong);
    }

    /// The binary blob ops surface their bytes as hex on the JSON wire —
    /// strict hex, so a JSON peer cannot smuggle malformed bodies past the
    /// decoder, and the source field is mandatory (no CLI-convenience
    /// default: only cluster clients speak these ops).
    #[test]
    fn bin_ops_validate_their_fields() {
        let put = decode_request(r#"{"op":"store_put_bin","data":"46474d53"}"#).unwrap();
        assert_eq!(put, Request::StorePutBin { data: b"FGMS".to_vec() });
        assert!(decode_request(r#"{"op":"store_put_bin"}"#).is_err());
        assert!(decode_request(r#"{"op":"store_put_bin","data":"zz"}"#).is_err());
        assert!(decode_request(r#"{"op":"store_put_bin","data":"abc"}"#).is_err());
        assert!(decode_request(r#"{"op":"stream_merge_bin","stream":"s"}"#).is_err());
        assert!(decode_request(r#"{"op":"stream_merge_bin","data":"ab"}"#).is_err());
        assert!(decode_request(r#"{"op":"sketch_fetch_bin","name":"a"}"#).is_err());
        assert!(
            decode_request(r#"{"op":"sketch_fetch_bin","name":"a","source":"disk"}"#).is_err()
        );
        let fetch =
            decode_request(r#"{"op":"sketch_fetch_bin","name":"a","source":"stream"}"#)
                .unwrap();
        assert_eq!(
            fetch,
            Request::SketchFetchBin { name: "a".into(), source: SketchSource::Stream }
        );
        assert!(decode_response(r#"{"ok":true,"type":"sketch_blob_bin","name":"a"}"#).is_err());
        assert!(
            decode_response(r#"{"ok":true,"type":"sketch_blob_bin","name":"a","data":"q"}"#)
                .is_err()
        );
    }

    #[test]
    fn sketch_fetch_source_is_optional_but_validated() {
        // Missing source defaults to the keyed store.
        let req = decode_request(r#"{"op":"sketch_fetch","name":"a"}"#).unwrap();
        assert_eq!(
            req,
            Request::SketchFetch { name: "a".into(), source: SketchSource::Store }
        );
        // Every named source decodes.
        for (text, want) in [
            ("store", SketchSource::Store),
            ("registry", SketchSource::Registry),
            ("stream", SketchSource::Stream),
        ] {
            let req = decode_request(&format!(
                r#"{{"op":"sketch_fetch","name":"a","source":"{text}"}}"#
            ))
            .unwrap();
            assert_eq!(req, Request::SketchFetch { name: "a".into(), source: want });
        }
        // Unknown or non-string sources are rejected; so is a missing name.
        assert!(decode_request(r#"{"op":"sketch_fetch","name":"a","source":"disk"}"#).is_err());
        assert!(decode_request(r#"{"op":"sketch_fetch","name":"a","source":7}"#).is_err());
        assert!(decode_request(r#"{"op":"sketch_fetch"}"#).is_err());
    }

    #[test]
    fn hello_reply_requires_its_fields() {
        assert!(decode_response(r#"{"ok":true,"type":"hello","protocol":4}"#).is_err());
        assert!(decode_response(
            r#"{"ok":true,"type":"hello","protocol":4,"node":"n","epoch":0,"k":8,"seed":1,"algo":"fastgm","algos":"fastgm"}"#
        )
        .is_err(), "algos must be an array");
        let ok = decode_response(
            r#"{"ok":true,"type":"hello","protocol":4,"node":"n","epoch":0,"k":8,"seed":1,"algo":"fastgm","algos":["fastgm"]}"#,
        )
        .unwrap();
        let Response::Hello { info } = ok else { panic!("expected hello") };
        assert_eq!(info.protocol, PROTOCOL_VERSION);
        assert_eq!(info.algos, vec!["fastgm".to_string()]);
    }

    /// `upsert.version` is optional, but when present it must be a u64 —
    /// and the repair/walk ops validate their fields strictly.
    #[test]
    fn versioned_upsert_and_repair_ops_validate_fields() {
        let versioned = decode_request(
            r#"{"op":"upsert","key":"a","vector":{"ids":[1],"weights":[1]},"version":7}"#,
        )
        .unwrap();
        assert!(matches!(versioned, Request::Upsert { version: Some(7), .. }));
        assert!(decode_request(
            r#"{"op":"upsert","key":"a","vector":{"ids":[1],"weights":[1]},"version":"x"}"#
        )
        .is_err());
        assert!(decode_request(
            r#"{"op":"upsert","key":"a","vector":{"ids":[1],"weights":[1]},"version":-3}"#
        )
        .is_err());
        // store_keys: limit required, after optional-but-string.
        assert!(decode_request(r#"{"op":"store_keys"}"#).is_err());
        assert!(decode_request(r#"{"op":"store_keys","after":7,"limit":10}"#).is_err());
        let page = decode_request(r#"{"op":"store_keys","limit":10}"#).unwrap();
        assert_eq!(page, Request::StoreKeys { after: None, limit: 10 });
        // store_put / stream_merge need their payloads.
        assert!(decode_request(r#"{"op":"store_put"}"#).is_err());
        assert!(decode_request(r#"{"op":"stream_merge","stream":"s"}"#).is_err());
        assert!(decode_request(r#"{"op":"stream_merge","data":"ab"}"#).is_err());
        // keys responses reject malformed pairs.
        assert!(decode_response(r#"{"ok":true,"type":"keys","keys":[["a"]]}"#).is_err());
        assert!(decode_response(r#"{"ok":true,"type":"keys","keys":[[1,2]]}"#).is_err());
    }

    #[test]
    fn store_requests_require_their_fields() {
        assert!(decode_request(r#"{"op":"upsert","key":"a"}"#).is_err()); // no vector
        assert!(decode_request(r#"{"op":"delete"}"#).is_err()); // no key
        assert!(
            decode_request(r#"{"op":"topk","vector":{"ids":[1],"weights":[1]}}"#).is_err(),
            "topk without a limit must not decode"
        );
        assert!(decode_request(r#"{"op":"snapshot"}"#).is_err()); // no path
        assert!(decode_request(r#"{"op":"restore"}"#).is_err()); // no path
        let ok = decode_request(
            r#"{"op":"upsert","key":"a","vector":{"ids":[1],"weights":[0.5]}}"#,
        )
        .unwrap();
        assert_eq!(ok.op(), "upsert");
    }

    #[test]
    fn rejects_unknown_ops() {
        assert!(decode_request(r#"{"op":"explode"}"#).is_err());
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"op":"sketch"}"#).is_err()); // missing fields
    }

    #[test]
    fn sketch_algo_is_optional_but_must_be_a_string() {
        let no_algo = decode_request(
            r#"{"op":"sketch","name":"d","vector":{"ids":[1],"weights":[1]}}"#,
        )
        .unwrap();
        assert!(matches!(no_algo, Request::Sketch { algo: None, .. }));
        let with = decode_request(
            r#"{"op":"sketch","name":"d","vector":{"ids":[1],"weights":[1]},"algo":"icws"}"#,
        )
        .unwrap();
        let Request::Sketch { algo, .. } = with else { panic!("expected sketch") };
        assert_eq!(algo.as_deref(), Some("icws"));
        // Decode does NOT validate the name — the service resolves it via
        // the engine registry and answers with an error response.
        assert!(decode_request(
            r#"{"op":"sketch","name":"d","vector":{"ids":[],"weights":[]},"algo":"nope"}"#
        )
        .is_ok());
        assert!(decode_request(
            r#"{"op":"sketch","name":"d","vector":{"ids":[],"weights":[]},"algo":7}"#
        )
        .is_err());
    }

    /// Gumbel-Max is undefined for negative/NaN/inf weights — the ingress
    /// decode must reject them loudly, naming the offending index, on
    /// every vector-carrying op (they all share `vector_from_json`).
    #[test]
    fn vector_decode_rejects_non_finite_and_negative_weights() {
        for op in ["sketch\",\"name\":\"d", "upsert\",\"key\":\"d", "topk\",\"limit\":3"] {
            let line =
                format!(r#"{{"op":"{op}","vector":{{"ids":[1,2],"weights":[0.5,-1.0]}}}}"#);
            let err = decode_request(&line).unwrap_err().to_string();
            assert!(err.contains("index 1"), "for {line}: {err}");
            assert!(err.contains("non-negative finite"), "{err}");
        }
        // lsh_query shares the same decode.
        assert!(decode_request(
            r#"{"op":"lsh_query","vector":{"ids":[9],"weights":[-0.25]},"limit":1}"#
        )
        .is_err());
        // Zero weights stay legal (sketchers filter them; replicated
        // writers send them today).
        assert!(decode_request(
            r#"{"op":"upsert","key":"d","vector":{"ids":[1],"weights":[0]}}"#
        )
        .is_ok());
        // The guard itself also stops NaN/inf (reachable via the framed
        // decode path, which carries raw f64 bits).
        assert!(check_weights(&[1.0, f64::NAN]).is_err());
        assert!(check_weights(&[f64::INFINITY]).is_err());
        assert!(check_weights(&[f64::NEG_INFINITY]).is_err());
        assert!(check_weights(&[0.0, 1.5]).is_ok());
    }

    #[test]
    fn sample_and_partition_targets_are_exactly_one_of_key_keys_stream() {
        // The single-key convenience form.
        let one = decode_request(r#"{"op":"sample","key":"a","n":4,"seed":9}"#).unwrap();
        assert_eq!(
            one,
            Request::Sample { target: QueryTarget::key("a"), n: 4, seed: 9 }
        );
        // Multi-key union and stream forms.
        let many =
            decode_request(r#"{"op":"partition","keys":["a","b"]}"#).unwrap();
        assert_eq!(
            many,
            Request::Partition { target: QueryTarget::Keys(vec!["a".into(), "b".into()]) }
        );
        let stream =
            decode_request(r#"{"op":"sample","stream":"pkts","n":1,"seed":0}"#).unwrap();
        assert!(matches!(
            stream,
            Request::Sample { target: QueryTarget::Stream(_), .. }
        ));
        // Zero or two target fields are loud errors.
        assert!(decode_request(r#"{"op":"sample","n":1,"seed":0}"#).is_err());
        assert!(decode_request(
            r#"{"op":"sample","key":"a","stream":"s","n":1,"seed":0}"#
        )
        .is_err());
        assert!(decode_request(r#"{"op":"partition"}"#).is_err());
        // n and seed are required on sample; bad shapes rejected.
        assert!(decode_request(r#"{"op":"sample","key":"a","seed":0}"#).is_err());
        assert!(decode_request(r#"{"op":"sample","key":"a","n":1}"#).is_err());
        assert!(decode_request(r#"{"op":"sample","keys":"a","n":1,"seed":0}"#).is_err());
        assert!(decode_request(r#"{"op":"sample","key":7,"n":1,"seed":0}"#).is_err());
    }
}
