//! The pooled coordinator: a thin concurrency shell around the
//! transport-agnostic [`Node`] core.
//!
//! All request *execution* lives in [`super::node`] — this module only adds
//! the worker pool (per-worker bounded queues + reusable
//! [`crate::sketch::SketchScratch`]), admission/backpressure, and the
//! latency/queue-depth observation that only makes sense once requests
//! queue. The TCP server, the CLI and the cluster layer all drive a
//! `Coordinator`; library embedders that want single-threaded, in-process
//! execution can drive a [`Node`] directly via [`Node::execute`].

use super::backpressure::Policy;
use super::node::Node;
use super::protocol::{Request, Response};
use super::worker::{Job, WorkerContext, WorkerPool};
use std::sync::Arc;
use std::time::Instant;

// Re-exported so existing `service::CoordinatorConfig` callers keep
// working; the config lives with the node core it configures.
pub use super::node::CoordinatorConfig;

pub struct Coordinator {
    node: Arc<Node>,
    pool: WorkerPool,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        let policy = if cfg.shed { Policy::Shed } else { Policy::Block };
        let (workers, queue_capacity) = (cfg.workers, cfg.queue_capacity);
        let node = Arc::new(Node::new(cfg)?);
        let handler = {
            let node = node.clone();
            Arc::new(move |req: Request, ctx: &mut WorkerContext| {
                node.execute(req, &mut ctx.scratch)
            })
        };
        let pool = WorkerPool::new(workers, queue_capacity, policy, handler);
        Ok(Coordinator { node, pool })
    }

    /// The transport-agnostic execution core. Hand this to embedders that
    /// need typed, pool-less access to the same state the pool serves.
    pub fn node(&self) -> &Arc<Node> {
        &self.node
    }

    /// Synchronous request (used by CLI / examples / per-connection loops).
    pub fn call(&self, req: Request) -> Response {
        let op = req.op();
        let t0 = Instant::now();
        if matches!(req, Request::Metrics) {
            self.observe_queue_depth();
        }
        let resp = self.pool.call(req);
        self.node.metrics().observe(op, t0.elapsed().as_secs_f64());
        resp
    }

    /// Async submit (load generators).
    pub fn submit(&self, req: Request) -> std::sync::mpsc::Receiver<Response> {
        self.node.metrics().incr(&format!("submit.{}", req.op()));
        if matches!(req, Request::Metrics) {
            self.observe_queue_depth();
        }
        self.pool.submit(req)
    }

    /// Batch admission for the event transport: one readable wakeup's
    /// worth of decoded frames enters the per-worker queues in a single
    /// pass ([`WorkerPool::submit_batch`]); rejected jobs are answered
    /// through their own reply paths, so the caller never tracks which
    /// slots were admitted.
    pub fn submit_jobs(&self, jobs: Vec<Job>) {
        self.pool.submit_batch(jobs);
    }

    /// Refresh the `queue_depth` gauge from the per-worker queue counters.
    /// The metrics snapshot is the gauge's only consumer, so it is sampled
    /// exactly when a `Request::Metrics` is admitted (the depth the report
    /// will describe) instead of locking the gauge map on every request —
    /// the sketch hot path stays free of metrics-side mutexes.
    fn observe_queue_depth(&self) {
        self.node.metrics().gauge_set("queue_depth", self.pool.queue_depth() as f64);
    }

    /// Current depth across the per-worker queues.
    pub fn queue_depth(&self) -> u64 {
        self.pool.queue_depth()
    }

    pub fn accel_enabled(&self) -> bool {
        self.node.accel_enabled()
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Value {
        self.node.metrics_snapshot()
    }

    pub fn config(&self) -> &CoordinatorConfig {
        self.node.config()
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
        // The node (and its batcher thread) drains once the last Arc drops:
        // make that explicit here.
        match Arc::try_unwrap(self.node) {
            Ok(node) => node.shutdown(),
            Err(_) => log::warn!("coordinator node still referenced at shutdown"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::engine::{self, EngineParams};
    use crate::sketch::{AlgorithmId, Sketcher, SparseVector};

    fn coord() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            k: 128,
            workers: 2,
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    fn vecs() -> (SparseVector, SparseVector) {
        (
            SparseVector::new(vec![1, 2, 3, 4], vec![1.0, 0.5, 2.0, 1.0]),
            SparseVector::new(vec![1, 2, 3, 9], vec![1.0, 0.5, 2.0, 1.5]),
        )
    }

    #[test]
    fn sketch_store_jaccard_flow() {
        let c = coord();
        let (u, v) = vecs();
        let truth = crate::estimate::jaccard::probability_jaccard(&u, &v);
        assert!(matches!(
            c.call(Request::Sketch { name: "u".into(), vector: u, algo: None }),
            Response::Sketch { .. }
        ));
        assert!(matches!(
            c.call(Request::Sketch { name: "v".into(), vector: v, algo: None }),
            Response::Sketch { .. }
        ));
        let Response::Estimate { value } = c.call(Request::Jaccard { a: "u".into(), b: "v".into() })
        else {
            panic!("expected estimate")
        };
        assert!((value - truth).abs() < 0.2, "est={value} truth={truth}");
        c.shutdown();
    }

    #[test]
    fn stream_cardinality_flow() {
        let c = coord();
        let items: Vec<(u64, f64)> = (0..300).map(|i| (i, 1.0)).collect();
        c.call(Request::Push { stream: "s".into(), items: items.clone() });
        c.call(Request::Push { stream: "s".into(), items }); // duplicates
        let Response::Estimate { value } = c.call(Request::Cardinality { stream: "s".into() })
        else {
            panic!("expected estimate")
        };
        assert!((value - 300.0).abs() / 300.0 < 0.25, "est={value}");
        c.shutdown();
    }

    #[test]
    fn dense_sketch_and_family_separation() {
        let c = coord();
        let dense: Vec<f64> = (0..64).map(|i| (i % 7) as f64 * 0.3).collect();
        let Response::Sketch { sketch, .. } =
            c.call(Request::SketchDense { name: "d".into(), weights: dense })
        else {
            panic!("expected sketch")
        };
        assert_eq!(sketch.family, crate::sketch::Family::Direct);
        // Cross-family comparison must error.
        let (u, _) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u, algo: None });
        let resp = c.call(Request::Jaccard { a: "u".into(), b: "d".into() });
        assert!(matches!(resp, Response::Error { .. }), "got {resp:?}");
        c.shutdown();
    }

    #[test]
    fn merge_and_lsh_flow() {
        let c = coord();
        let (u, v) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u.clone(), algo: None });
        c.call(Request::Sketch { name: "v".into(), vector: v, algo: None });
        let Response::Sketch { sketch: merged, .. } =
            c.call(Request::Merge { names: vec!["u".into(), "v".into()], out: "m".into() })
        else {
            panic!("expected merged sketch")
        };
        assert_eq!(merged.k(), 128);
        // LSH: index u and v, query with u — u must be the top hit.
        c.call(Request::LshInsert { name: "u".into() });
        c.call(Request::LshInsert { name: "v".into() });
        let Response::TopK { hits } = c.call(Request::LshQuery { vector: u, limit: 2 }) else {
            panic!("expected topk")
        };
        assert_eq!(hits[0].0, "u");
        assert!((hits[0].1 - 1.0).abs() < 1e-9);
        c.shutdown();
    }

    #[test]
    fn large_sketches_route_through_shards_bit_identically() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 64,
            workers: 2,
            shards: 4,
            shard_min_nplus: 100, // force the sharded path for this vector
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let v = SparseVector::new(
            (0..500u64).map(|i| i * 7 + 1).collect(),
            (0..500).map(|i| 0.1 + (i % 13) as f64 * 0.5).collect(),
        );
        let Response::Sketch { sketch, .. } =
            c.call(Request::Sketch { name: "big".into(), vector: v.clone(), algo: None })
        else {
            panic!("expected sketch")
        };
        // Bit-identical to single-threaded FastGM at the same (k, seed).
        let single = crate::sketch::fastgm::FastGm::new(64, 42).sketch(&v);
        assert_eq!(sketch, single);
        // The sharded path counter must have fired.
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let sharded = snapshot
            .get("counters")
            .and_then(|c| c.get("path.sketch.sharded"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        assert!(sharded >= 1.0, "sharded path not taken: {snapshot}");
        c.shutdown();
    }

    #[test]
    fn algo_field_routes_through_the_engine_registry() {
        let c = coord();
        let (u, _) = vecs();
        // Every registered algorithm is reachable per request.
        for id in AlgorithmId::ALL {
            let Response::Sketch { sketch, .. } = c.call(Request::Sketch {
                name: format!("u-{}", id.name()),
                vector: u.clone(),
                algo: Some(id.name().to_string()),
            }) else {
                panic!("algo {} not served", id.name())
            };
            assert_eq!(sketch.family, id.family(), "family for {}", id.name());
            assert_eq!(sketch.k(), 128);
            // Identical to a direct registry build at the coordinator's
            // (k, seed) — per-worker scratch reuse must be invisible.
            let direct = engine::build(id, EngineParams::new(128, 42).with_shards(4)).sketch(&u);
            assert_eq!(sketch, direct, "engine {} diverged through the service", id.name());
        }
        // Unknown names become error responses listing the registry.
        let resp = c.call(Request::Sketch {
            name: "x".into(),
            vector: u,
            algo: Some("quantum".into()),
        });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("unknown sketch algorithm 'quantum'"), "{message}");
        c.shutdown();
    }

    #[test]
    fn configured_default_algo_is_validated_and_used() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 64,
            workers: 1,
            algo: "pminhash".into(),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, _) = vecs();
        let Response::Sketch { sketch, .. } =
            c.call(Request::Sketch { name: "u".into(), vector: u, algo: None })
        else {
            panic!("expected sketch")
        };
        assert_eq!(sketch.family, crate::sketch::Family::Direct);
        c.shutdown();
        // A bad default fails at construction, not per request.
        assert!(Coordinator::new(CoordinatorConfig {
            algo: "warpdrive".into(),
            ..CoordinatorConfig::default()
        })
        .is_err());
    }

    #[test]
    fn scratch_and_queue_metrics_are_reported() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1, // one worker → second sketch must reuse its scratch
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, v) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u, algo: None });
        c.call(Request::Sketch { name: "v".into(), vector: v, algo: None });
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let counter = |name: &str| {
            snapshot
                .get("counters")
                .and_then(|c| c.get(name))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        assert_eq!(counter("scratch.alloc"), 1.0, "{snapshot}");
        assert!(counter("scratch.reuse") >= 1.0, "{snapshot}");
        // queue_depth gauge present (0 once everything drained).
        let depth = snapshot
            .get("gauges")
            .and_then(|g| g.get("queue_depth"))
            .and_then(|v| v.as_f64());
        assert!(depth.is_some(), "queue_depth gauge missing: {snapshot}");
        assert_eq!(c.queue_depth(), 0);
        c.shutdown();
    }

    #[test]
    fn store_upsert_topk_delete_flow() {
        // scan threshold 1 → the second upsert already exercises the probe.
        let c = Coordinator::new(CoordinatorConfig {
            k: 128,
            workers: 2,
            topk_scan_max: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, v) = vecs();
        for (key, vec) in [("u", &u), ("v", &v)] {
            assert!(matches!(
                c.call(Request::Upsert { key: key.into(), vector: vec.clone(), version: None }),
                Response::Ack { .. }
            ));
        }
        let Response::TopK { hits } = c.call(Request::TopK { vector: u.clone(), limit: 2 })
        else {
            panic!("expected topk")
        };
        assert_eq!(hits[0].0, "u");
        assert!((hits[0].1 - 1.0).abs() < 1e-9);
        // Stats reflect the two entries.
        let Response::Stats { stats } = c.call(Request::StoreStats) else {
            panic!("expected stats")
        };
        assert_eq!(stats.get("size").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(stats.get("lsh_size").and_then(|v| v.as_f64()), Some(2.0));
        // Delete is idempotent and updates the index.
        let Response::Ack { info } = c.call(Request::Delete { key: "u".into() }) else {
            panic!("expected ack")
        };
        assert!(info.contains("deleted"));
        let Response::Ack { info } = c.call(Request::Delete { key: "u".into() }) else {
            panic!("expected ack")
        };
        assert!(info.contains("no entry"));
        let Response::TopK { hits } = c.call(Request::TopK { vector: u, limit: 2 }) else {
            panic!("expected topk")
        };
        assert!(hits.iter().all(|h| h.0 != "u"), "deleted key still served: {hits:?}");
        // Metrics carry the store gauges and top-k counters.
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let gauge = |name: &str| {
            snapshot.get("gauges").and_then(|g| g.get(name)).and_then(|v| v.as_f64())
        };
        assert_eq!(gauge("store.size"), Some(1.0), "{snapshot}");
        assert_eq!(gauge("store.lsh_size"), Some(1.0), "{snapshot}");
        let counter = |name: &str| {
            snapshot
                .get("counters")
                .and_then(|c| c.get(name))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        assert!(counter("topk.candidates") >= 1.0, "{snapshot}");
        assert!(counter("path.topk.probe") >= 1.0, "{snapshot}");
        c.shutdown();
    }

    #[test]
    fn store_snapshot_restores_across_coordinators() {
        let path = std::env::temp_dir().join(format!(
            "fastgm-service-snap-{}.fgms",
            std::process::id()
        ));
        let path_str = path.to_string_lossy().to_string();
        let cfg = CoordinatorConfig { k: 64, workers: 2, ..CoordinatorConfig::default() };
        let (u, v) = vecs();
        let c = Coordinator::new(cfg.clone()).unwrap();
        c.call(Request::Upsert { key: "u".into(), vector: u.clone(), version: None });
        c.call(Request::Upsert { key: "v".into(), vector: v, version: None });
        let Response::Ack { info } = c.call(Request::Snapshot { path: path_str.clone() })
        else {
            panic!("expected ack")
        };
        assert!(info.contains("2 entries"), "{info}");
        let Response::TopK { hits: before } =
            c.call(Request::TopK { vector: u.clone(), limit: 2 })
        else {
            panic!("expected topk")
        };
        c.shutdown();

        // A fresh coordinator (cold store) warm-restarts from the snapshot.
        let c2 = Coordinator::new(cfg).unwrap();
        let Response::Ack { info } = c2.call(Request::Restore { path: path_str.clone() })
        else {
            panic!("expected ack, restore failed")
        };
        assert!(info.contains("restored 2 entries"), "{info}");
        let Response::TopK { hits: after } = c2.call(Request::TopK { vector: u, limit: 2 })
        else {
            panic!("expected topk")
        };
        assert_eq!(before, after, "restored store must answer identically");
        // A mismatched config refuses the snapshot cleanly.
        let c3 = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let resp = c3.call(Request::Restore { path: path_str });
        assert!(matches!(resp, Response::Error { .. }), "got {resp:?}");
        c3.shutdown();
        c2.shutdown();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn oversized_store_keys_are_refused_at_upsert() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, _) = vecs();
        let giant = "k".repeat(crate::sketch::codec::MAX_KEY_LEN + 1);
        let resp = c.call(Request::Upsert { key: giant, vector: u.clone(), version: None });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("limited to"), "{message}");
        // At the bound itself, the upsert is accepted and snapshottable.
        let exact = "k".repeat(crate::sketch::codec::MAX_KEY_LEN);
        assert!(matches!(
            c.call(Request::Upsert { key: exact, vector: u, version: None }),
            Response::Ack { .. }
        ));
        c.shutdown();
    }

    #[test]
    fn store_ops_refuse_non_race_default_algos() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1,
            algo: "minhash".into(),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, _) = vecs();
        for req in [
            Request::Upsert { key: "u".into(), vector: u.clone(), version: None },
            Request::TopK { vector: u, limit: 1 },
            Request::Restore { path: "/nonexistent".into() },
        ] {
            let resp = c.call(req);
            let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
            assert!(message.contains("EXP-register"), "{message}");
        }
        c.shutdown();
    }

    #[test]
    fn errors_are_responses_not_panics() {
        let c = coord();
        assert!(matches!(
            c.call(Request::GetSketch { name: "ghost".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Cardinality { stream: "ghost".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Merge { names: vec![], out: "x".into() }),
            Response::Error { .. }
        ));
        // Store persistence I/O failures are error responses too.
        assert!(matches!(
            c.call(Request::Restore { path: "/definitely/not/here.fgms".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Snapshot { path: "/definitely/not/here/snap.fgms".into() }),
            Response::Error { .. }
        ));
        c.shutdown();
    }

    #[test]
    fn metrics_reflect_traffic() {
        let c = coord();
        c.call(Request::Ping);
        c.call(Request::Ping);
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let pings = snapshot
            .get("counters")
            .and_then(|c| c.get("ops.ping"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(pings >= 2.0);
        c.shutdown();
    }

    /// The pooled path and the bare node path execute identically — the
    /// coordinator adds concurrency, never semantics.
    #[test]
    fn pooled_and_direct_node_execution_agree() {
        let c = coord();
        let (u, _) = vecs();
        let Response::Sketch { sketch: pooled, .. } =
            c.call(Request::Sketch { name: "u".into(), vector: u.clone(), algo: None })
        else {
            panic!("expected sketch")
        };
        let Response::Sketch { sketch: direct, .. } = c.node().execute_alloc(Request::Sketch {
            name: "u2".into(),
            vector: u,
            algo: None,
        }) else {
            panic!("expected sketch")
        };
        assert_eq!(pooled, direct);
        // Both wrote into the same shared registry.
        assert!(matches!(
            c.call(Request::GetSketch { name: "u2".into() }),
            Response::Sketch { .. }
        ));
        c.shutdown();
    }
}
