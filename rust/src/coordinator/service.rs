//! The coordinator service: wires registry, router, worker pool, batcher,
//! LSH index, metrics and (optionally) the PJRT accelerator into one
//! request handler. This is what the TCP server, the CLI and the examples
//! all drive.
//!
//! Family discipline (README.md §RNG-families): the `sketch` op always produces
//! **Ordered**-family FastGM sketches; `sketch_dense` always produces
//! **Direct**-family sketches (accelerator or CPU P-MinHash fallback —
//! identical semantics). Estimators reject cross-family pairs, so a
//! mis-routed comparison fails loudly instead of silently biasing.

use super::backpressure::Policy;
use super::batcher::{BatcherConfig, DenseBatcher};
use super::merger::merge_tree;
use super::metrics::Metrics;
use super::protocol::{Request, Response};
use super::registry::Registry;
use super::router::{Path, Router, RouterConfig};
use super::worker::WorkerPool;
use crate::estimate::cardinality::{estimate_cardinality, estimate_weighted_jaccard};
use crate::estimate::jaccard::estimate_jp;
use crate::lsh::{LshIndex, LshParams};
use crate::sketch::fastgm::FastGm;
use crate::sketch::sharded::ShardedSketcher;
use crate::sketch::{GumbelMaxSketch, Sketcher, SparseVector};
use crate::util::config::Config;
use crate::util::hash::token_id;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub k: usize,
    pub seed: u64,
    pub workers: usize,
    pub queue_capacity: usize,
    pub shed: bool,
    /// Artifact directory; None (or missing manifest) disables the
    /// accelerator — everything runs on CPU with identical semantics.
    pub artifacts_dir: Option<String>,
    pub batch_max: usize,
    pub batch_deadline: Duration,
    pub lsh_threshold: f64,
    /// Shard team size for large sparse `sketch` requests (§2.3 parallel
    /// shard-merge; 1 disables). The sharded result is bit-identical to
    /// single-threaded FastGM.
    pub shards: usize,
    /// Smallest n⁺ routed to the shard team.
    pub shard_min_nplus: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            k: 256,
            seed: 42,
            workers: 4,
            queue_capacity: 1024,
            shed: false,
            artifacts_dir: None,
            batch_max: 8,
            batch_deadline: Duration::from_millis(2),
            lsh_threshold: 0.5,
            shards: 4,
            shard_min_nplus: 4096,
        }
    }
}

impl CoordinatorConfig {
    /// Read from a parsed TOML-subset [`Config`] (the launcher path).
    pub fn from_config(cfg: &Config) -> CoordinatorConfig {
        let d = CoordinatorConfig::default();
        CoordinatorConfig {
            k: cfg.usize("sketch.k", d.k),
            seed: cfg.u64("sketch.seed", d.seed),
            workers: cfg.usize("server.workers", d.workers),
            queue_capacity: cfg.usize("server.queue_capacity", d.queue_capacity),
            shed: cfg.bool("server.shed", d.shed),
            artifacts_dir: {
                let dir = cfg.str("accel.artifacts_dir", "artifacts");
                if dir.is_empty() || dir == "off" {
                    None
                } else {
                    Some(dir)
                }
            },
            batch_max: cfg.usize("accel.max_batch", d.batch_max),
            batch_deadline: Duration::from_micros(
                (cfg.f64("accel.deadline_ms", 2.0) * 1000.0) as u64,
            ),
            lsh_threshold: cfg.f64("lsh.threshold", d.lsh_threshold),
            shards: cfg.usize("sketch.shards", d.shards),
            shard_min_nplus: cfg.usize("sketch.shard_min_nplus", d.shard_min_nplus),
        }
    }
}

struct Inner {
    cfg: CoordinatorConfig,
    registry: Registry,
    metrics: Metrics,
    fastgm: FastGm,
    sharded: ShardedSketcher,
    router: Router,
    batcher: DenseBatcher,
    lsh: RwLock<LshIndex>,
    lsh_names: RwLock<HashMap<u64, String>>,
    accel_on: bool,
}

pub struct Coordinator {
    inner: Arc<Inner>,
    pool: WorkerPool,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        // Bucket metadata comes from the manifest WITHOUT touching PJRT
        // (the xla wrapper types are !Send); the batcher thread owns the
        // actual runtime.
        let (accel_dir, accel_max_len) = match &cfg.artifacts_dir {
            // Without the `accel` feature a manifest may parse but can never
            // be loaded: report the accelerator as off (accel_enabled(),
            // metrics, router max_len) instead of advertising a path that
            // cannot exist. Dense requests still flow through the batcher's
            // CPU fallback.
            Some(dir) if !cfg!(feature = "accel") => {
                log::warn!("accel.artifacts_dir '{dir}' ignored: built without the `accel` feature");
                (None, 0)
            }
            Some(dir) => match crate::runtime::read_manifest(dir) {
                Ok(specs) => {
                    let max_len = specs
                        .iter()
                        .filter(|s| {
                            s.name.starts_with("sketch_b")
                                && s.outputs.first().map(|o| o.shape[1]) == Some(cfg.k)
                        })
                        .map(|s| s.inputs[1].shape[1])
                        .max()
                        .unwrap_or(0);
                    (Some(dir.clone()), max_len)
                }
                Err(e) => {
                    log::warn!("accelerator disabled: {e}");
                    (None, 0)
                }
            },
            None => (None, 0),
        };
        let accel_on = accel_dir.is_some();
        let batcher = DenseBatcher::new(
            BatcherConfig {
                max_batch: cfg.batch_max,
                deadline: cfg.batch_deadline,
                k: cfg.k,
                seed: cfg.seed as u32,
            },
            accel_dir,
        );
        let inner = Arc::new(Inner {
            fastgm: FastGm::new(cfg.k, cfg.seed),
            sharded: ShardedSketcher::new(cfg.k, cfg.seed, cfg.shards.max(1)),
            router: Router::new(RouterConfig {
                accel_max_len,
                min_density: 0.25,
                shards: cfg.shards.max(1),
                shard_min_nplus: cfg.shard_min_nplus,
            }),
            registry: Registry::new(),
            metrics: Metrics::new(),
            batcher,
            lsh: RwLock::new(LshIndex::new(LshParams::for_threshold(cfg.k, cfg.lsh_threshold))),
            lsh_names: RwLock::new(HashMap::new()),
            accel_on,
            cfg: cfg.clone(),
        });
        let handler = {
            let inner = inner.clone();
            Arc::new(move |req: Request| inner.handle(req))
        };
        let policy = if cfg.shed { Policy::Shed } else { Policy::Block };
        let pool = WorkerPool::new(cfg.workers, cfg.queue_capacity, policy, handler);
        Ok(Coordinator { inner, pool })
    }

    /// Synchronous request (used by CLI / examples / per-connection loops).
    pub fn call(&self, req: Request) -> Response {
        let op = req.op();
        let t0 = Instant::now();
        let resp = self.pool.call(req);
        self.inner.metrics.observe(op, t0.elapsed().as_secs_f64());
        resp
    }

    /// Async submit (load generators).
    pub fn submit(&self, req: Request) -> std::sync::mpsc::Receiver<Response> {
        self.inner.metrics.incr(&format!("submit.{}", req.op()));
        self.pool.submit(req)
    }

    pub fn accel_enabled(&self) -> bool {
        self.inner.accel_on
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Value {
        self.inner.metrics.snapshot()
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.inner.cfg
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
        // inner.batcher shut down on drop of last Arc: explicit drain here.
        match Arc::try_unwrap(self.inner) {
            Ok(inner) => inner.batcher.shutdown(),
            Err(_) => log::warn!("coordinator inner still referenced at shutdown"),
        }
    }
}

impl Inner {
    /// Ordered-family sparse sketch, routed single-threaded or through the
    /// §2.3 shard team — identical output either way (the router only
    /// decides parallelism, never the algorithm family).
    fn sketch_sparse(&self, v: &SparseVector) -> GumbelMaxSketch {
        match self.router.route_sketch(v.n_plus()) {
            Path::ShardedCpu => {
                self.metrics.incr("path.sketch.sharded");
                self.sharded.sketch(v)
            }
            _ => {
                self.metrics.incr("path.sketch.single");
                self.fastgm.sketch(v)
            }
        }
    }

    fn handle(&self, req: Request) -> Response {
        match self.handle_inner(req) {
            Ok(resp) => resp,
            Err(e) => {
                self.metrics.incr("errors");
                Response::err(e)
            }
        }
    }

    fn handle_inner(&self, req: Request) -> anyhow::Result<Response> {
        Ok(match req {
            Request::Ping => Response::Pong,
            Request::Metrics => {
                let mut snap = self.metrics.snapshot();
                snap.set("sketches", crate::util::json::Value::num(self.registry.sketch_count() as f64));
                snap.set("streams", crate::util::json::Value::num(self.registry.stream_count() as f64));
                snap.set("accel", crate::util::json::Value::Bool(self.accel_on));
                snap.set("shards", crate::util::json::Value::num(self.cfg.shards as f64));
                snap.set(
                    "batch_flushes",
                    crate::util::json::Value::num(
                        self.batcher.flushes.load(std::sync::atomic::Ordering::Relaxed) as f64,
                    ),
                );
                Response::MetricsDump { snapshot: snap }
            }
            Request::Sketch { name, vector } => {
                let sk = self.sketch_sparse(&vector);
                self.registry.put_sketch(&name, sk.clone());
                Response::Sketch { name, sketch: sk }
            }
            Request::SketchDense { name, weights } => {
                // Router decides engine; both produce Direct-family
                // sketches via the batcher (accel or CPU fallback).
                let _path = self.router.route_dense(weights.len());
                let rx = self.batcher.submit(weights);
                let sk = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("batcher dropped request"))??;
                self.registry.put_sketch(&name, sk.clone());
                Response::Sketch { name, sketch: sk }
            }
            Request::GetSketch { name } => {
                let sk = self
                    .registry
                    .get_sketch(&name)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{name}'"))?;
                Response::Sketch { name, sketch: sk }
            }
            Request::Push { stream, items } => {
                let n = self.registry.stream_push(&stream, self.cfg.k, self.cfg.seed, &items);
                Response::Ack { info: format!("stream '{stream}' processed {n}") }
            }
            Request::Cardinality { stream } => {
                let sk = self
                    .registry
                    .stream_sketch(&stream)
                    .ok_or_else(|| anyhow::anyhow!("no stream named '{stream}'"))?;
                Response::Estimate { value: estimate_cardinality(&sk) }
            }
            Request::Jaccard { a, b } => {
                let sa = self
                    .registry
                    .get_sketch(&a)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{a}'"))?;
                let sb = self
                    .registry
                    .get_sketch(&b)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{b}'"))?;
                Response::Estimate { value: estimate_jp(&sa, &sb)? }
            }
            Request::WeightedJaccard { a, b } => {
                let sa = self
                    .registry
                    .get_sketch(&a)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{a}'"))?;
                let sb = self
                    .registry
                    .get_sketch(&b)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{b}'"))?;
                Response::Estimate { value: estimate_weighted_jaccard(&sa, &sb)? }
            }
            Request::Merge { names, out } => {
                anyhow::ensure!(!names.is_empty(), "merge needs at least one sketch");
                let sketches: Vec<_> = names
                    .iter()
                    .map(|n| {
                        self.registry
                            .get_sketch(n)
                            .ok_or_else(|| anyhow::anyhow!("no sketch named '{n}'"))
                    })
                    .collect::<anyhow::Result<_>>()?;
                let merged = merge_tree(&sketches, 4)?;
                self.registry.put_sketch(&out, merged.clone());
                Response::Sketch { name: out, sketch: merged }
            }
            Request::LshInsert { name } => {
                let sk = self
                    .registry
                    .get_sketch(&name)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{name}'"))?;
                let key = token_id(&name);
                self.lsh.write().unwrap().insert(key, sk);
                self.lsh_names.write().unwrap().insert(key, name.clone());
                Response::Ack { info: format!("indexed '{name}'") }
            }
            Request::LshQuery { vector, limit } => {
                let query = self.sketch_sparse(&vector);
                let hits = self.lsh.read().unwrap().query(&query, limit)?;
                let names = self.lsh_names.read().unwrap();
                Response::TopK {
                    hits: hits
                        .into_iter()
                        .map(|(key, score)| {
                            (
                                names.get(&key).cloned().unwrap_or_else(|| format!("#{key}")),
                                score,
                            )
                        })
                        .collect(),
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            k: 128,
            workers: 2,
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    fn vecs() -> (SparseVector, SparseVector) {
        (
            SparseVector::new(vec![1, 2, 3, 4], vec![1.0, 0.5, 2.0, 1.0]),
            SparseVector::new(vec![1, 2, 3, 9], vec![1.0, 0.5, 2.0, 1.5]),
        )
    }

    #[test]
    fn sketch_store_jaccard_flow() {
        let c = coord();
        let (u, v) = vecs();
        let truth = crate::estimate::jaccard::probability_jaccard(&u, &v);
        assert!(matches!(
            c.call(Request::Sketch { name: "u".into(), vector: u }),
            Response::Sketch { .. }
        ));
        assert!(matches!(
            c.call(Request::Sketch { name: "v".into(), vector: v }),
            Response::Sketch { .. }
        ));
        let Response::Estimate { value } = c.call(Request::Jaccard { a: "u".into(), b: "v".into() })
        else {
            panic!("expected estimate")
        };
        assert!((value - truth).abs() < 0.2, "est={value} truth={truth}");
        c.shutdown();
    }

    #[test]
    fn stream_cardinality_flow() {
        let c = coord();
        let items: Vec<(u64, f64)> = (0..300).map(|i| (i, 1.0)).collect();
        c.call(Request::Push { stream: "s".into(), items: items.clone() });
        c.call(Request::Push { stream: "s".into(), items }); // duplicates
        let Response::Estimate { value } = c.call(Request::Cardinality { stream: "s".into() })
        else {
            panic!("expected estimate")
        };
        assert!((value - 300.0).abs() / 300.0 < 0.25, "est={value}");
        c.shutdown();
    }

    #[test]
    fn dense_sketch_and_family_separation() {
        let c = coord();
        let dense: Vec<f64> = (0..64).map(|i| (i % 7) as f64 * 0.3).collect();
        let Response::Sketch { sketch, .. } =
            c.call(Request::SketchDense { name: "d".into(), weights: dense })
        else {
            panic!("expected sketch")
        };
        assert_eq!(sketch.family, crate::sketch::Family::Direct);
        // Cross-family comparison must error.
        let (u, _) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u });
        let resp = c.call(Request::Jaccard { a: "u".into(), b: "d".into() });
        assert!(matches!(resp, Response::Error { .. }), "got {resp:?}");
        c.shutdown();
    }

    #[test]
    fn merge_and_lsh_flow() {
        let c = coord();
        let (u, v) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u.clone() });
        c.call(Request::Sketch { name: "v".into(), vector: v });
        let Response::Sketch { sketch: merged, .. } =
            c.call(Request::Merge { names: vec!["u".into(), "v".into()], out: "m".into() })
        else {
            panic!("expected merged sketch")
        };
        assert_eq!(merged.k(), 128);
        // LSH: index u and v, query with u — u must be the top hit.
        c.call(Request::LshInsert { name: "u".into() });
        c.call(Request::LshInsert { name: "v".into() });
        let Response::TopK { hits } = c.call(Request::LshQuery { vector: u, limit: 2 }) else {
            panic!("expected topk")
        };
        assert_eq!(hits[0].0, "u");
        assert!((hits[0].1 - 1.0).abs() < 1e-9);
        c.shutdown();
    }

    #[test]
    fn large_sketches_route_through_shards_bit_identically() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 64,
            workers: 2,
            shards: 4,
            shard_min_nplus: 100, // force the sharded path for this vector
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let v = SparseVector::new(
            (0..500u64).map(|i| i * 7 + 1).collect(),
            (0..500).map(|i| 0.1 + (i % 13) as f64 * 0.5).collect(),
        );
        let Response::Sketch { sketch, .. } =
            c.call(Request::Sketch { name: "big".into(), vector: v.clone() })
        else {
            panic!("expected sketch")
        };
        // Bit-identical to single-threaded FastGM at the same (k, seed).
        let single = crate::sketch::fastgm::FastGm::new(64, 42).sketch(&v);
        assert_eq!(sketch, single);
        // The sharded path counter must have fired.
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let sharded = snapshot
            .get("counters")
            .and_then(|c| c.get("path.sketch.sharded"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        assert!(sharded >= 1.0, "sharded path not taken: {snapshot}");
        c.shutdown();
    }

    #[test]
    fn errors_are_responses_not_panics() {
        let c = coord();
        assert!(matches!(
            c.call(Request::GetSketch { name: "ghost".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Cardinality { stream: "ghost".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Merge { names: vec![], out: "x".into() }),
            Response::Error { .. }
        ));
        c.shutdown();
    }

    #[test]
    fn metrics_reflect_traffic() {
        let c = coord();
        c.call(Request::Ping);
        c.call(Request::Ping);
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let pings = snapshot
            .get("counters")
            .and_then(|c| c.get("ops.ping"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(pings >= 2.0);
        c.shutdown();
    }
}
