//! The coordinator service: wires registry, router, worker pool, batcher,
//! LSH index, metrics and (optionally) the PJRT accelerator into one
//! request handler. This is what the TCP server, the CLI and the examples
//! all drive.
//!
//! Family discipline (README.md §RNG-families): the `sketch` op always produces
//! **Ordered**-family FastGM sketches; `sketch_dense` always produces
//! **Direct**-family sketches (accelerator or CPU P-MinHash fallback —
//! identical semantics). Estimators reject cross-family pairs, so a
//! mis-routed comparison fails loudly instead of silently biasing.

use super::backpressure::Policy;
use super::batcher::{BatcherConfig, DenseBatcher};
use super::merger::merge_tree;
use super::metrics::Metrics;
use super::protocol::{Request, Response};
use super::registry::Registry;
use super::router::{Router, RouterConfig, SketchPlan, TopKPlan};
use super::store::SketchStore;
use super::worker::{WorkerContext, WorkerPool};
use crate::estimate::cardinality::{estimate_cardinality, estimate_weighted_jaccard};
use crate::estimate::jaccard::estimate_jp;
use crate::lsh::{LshIndex, LshParams};
use crate::sketch::engine::{self, EngineParams};
use crate::sketch::{AlgorithmId, GumbelMaxSketch, Sketcher, SparseVector};
use crate::util::config::Config;
use crate::util::hash::token_id;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub k: usize,
    pub seed: u64,
    pub workers: usize,
    pub queue_capacity: usize,
    pub shed: bool,
    /// Artifact directory; None (or missing manifest) disables the
    /// accelerator — everything runs on CPU with identical semantics.
    pub artifacts_dir: Option<String>,
    pub batch_max: usize,
    pub batch_deadline: Duration,
    pub lsh_threshold: f64,
    /// Shard team size for large sparse `sketch` requests (§2.3 parallel
    /// shard-merge; 1 disables). The sharded result is bit-identical to
    /// single-threaded FastGM.
    pub shards: usize,
    /// Smallest n⁺ routed to the shard team.
    pub shard_min_nplus: usize,
    /// Default engine-registry algorithm for `sketch` requests that carry
    /// no `algo` field (config key `sketch.algo`).
    pub algo: String,
    /// Lock shards of the keyed sketch store (config key `store.shards`).
    pub store_shards: usize,
    /// Largest store size a `topk` answers by brute-force scan instead of
    /// the LSH band probe (config key `store.topk_scan_max`).
    pub topk_scan_max: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            k: 256,
            seed: 42,
            workers: 4,
            queue_capacity: 1024,
            shed: false,
            artifacts_dir: None,
            batch_max: 8,
            batch_deadline: Duration::from_millis(2),
            lsh_threshold: 0.5,
            shards: 4,
            shard_min_nplus: 4096,
            algo: "fastgm".to_string(),
            store_shards: 8,
            topk_scan_max: 64,
        }
    }
}

impl CoordinatorConfig {
    /// Read from a parsed TOML-subset [`Config`] (the launcher path).
    pub fn from_config(cfg: &Config) -> CoordinatorConfig {
        let d = CoordinatorConfig::default();
        CoordinatorConfig {
            k: cfg.usize("sketch.k", d.k),
            seed: cfg.u64("sketch.seed", d.seed),
            workers: cfg.usize("server.workers", d.workers),
            queue_capacity: cfg.usize("server.queue_capacity", d.queue_capacity),
            shed: cfg.bool("server.shed", d.shed),
            artifacts_dir: {
                let dir = cfg.str("accel.artifacts_dir", "artifacts");
                if dir.is_empty() || dir == "off" {
                    None
                } else {
                    Some(dir)
                }
            },
            batch_max: cfg.usize("accel.max_batch", d.batch_max),
            batch_deadline: Duration::from_micros(
                (cfg.f64("accel.deadline_ms", 2.0) * 1000.0) as u64,
            ),
            lsh_threshold: cfg.f64("lsh.threshold", d.lsh_threshold),
            shards: cfg.usize("sketch.shards", d.shards),
            shard_min_nplus: cfg.usize("sketch.shard_min_nplus", d.shard_min_nplus),
            algo: cfg.str("sketch.algo", &d.algo),
            store_shards: cfg.usize("store.shards", d.store_shards),
            topk_scan_max: cfg.usize("store.topk_scan_max", d.topk_scan_max),
        }
    }
}

struct Inner {
    cfg: CoordinatorConfig,
    registry: Registry,
    metrics: Metrics,
    router: Router,
    batcher: DenseBatcher,
    lsh: RwLock<LshIndex>,
    lsh_names: RwLock<HashMap<u64, String>>,
    /// Keyed similarity-serving store (upsert/delete/topk/snapshot ops).
    store: SketchStore,
    accel_on: bool,
    /// Resolved `cfg.algo` (validated at construction time).
    default_algo: AlgorithmId,
    /// Engine-registry construction parameters shared by all algorithms.
    engine_params: EngineParams,
    /// Registry sketchers, shared across workers (stateless; all
    /// per-request state lives in the per-worker scratch). The ONLY
    /// construction path for sketchers — pre-seeded with the hot entries,
    /// lazily extended per requested `algo` — so (k, seed, shards) can
    /// never diverge between the default path and per-request overrides.
    engines: RwLock<HashMap<AlgorithmId, Arc<dyn Sketcher>>>,
}

pub struct Coordinator {
    inner: Arc<Inner>,
    pool: WorkerPool,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        // Bucket metadata comes from the manifest WITHOUT touching PJRT
        // (the xla wrapper types are !Send); the batcher thread owns the
        // actual runtime.
        let (accel_dir, accel_max_len) = match &cfg.artifacts_dir {
            // Without the `accel` feature a manifest may parse but can never
            // be loaded: report the accelerator as off (accel_enabled(),
            // metrics, router max_len) instead of advertising a path that
            // cannot exist. Dense requests still flow through the batcher's
            // CPU fallback.
            Some(dir) if !cfg!(feature = "accel") => {
                log::warn!("accel.artifacts_dir '{dir}' ignored: built without the `accel` feature");
                (None, 0)
            }
            Some(dir) => match crate::runtime::read_manifest(dir) {
                Ok(specs) => {
                    let max_len = specs
                        .iter()
                        .filter(|s| {
                            s.name.starts_with("sketch_b")
                                && s.outputs.first().map(|o| o.shape[1]) == Some(cfg.k)
                        })
                        .map(|s| s.inputs[1].shape[1])
                        .max()
                        .unwrap_or(0);
                    (Some(dir.clone()), max_len)
                }
                Err(e) => {
                    log::warn!("accelerator disabled: {e}");
                    (None, 0)
                }
            },
            None => (None, 0),
        };
        // A misconfigured default algorithm fails loudly at startup instead
        // of per request (checked before any thread is spawned).
        let default_algo = AlgorithmId::from_name(&cfg.algo)?;
        let accel_on = accel_dir.is_some();
        let batcher = DenseBatcher::new(
            BatcherConfig {
                max_batch: cfg.batch_max,
                deadline: cfg.batch_deadline,
                k: cfg.k,
                seed: cfg.seed,
            },
            accel_dir,
        );
        let engine_params =
            EngineParams::new(cfg.k, cfg.seed).with_shards(cfg.shards.max(1));
        // Pre-seed the hot registry entries (default algo + both routed
        // FastGM paths) so steady-state requests never take the write lock.
        let mut engines: HashMap<AlgorithmId, Arc<dyn Sketcher>> = HashMap::new();
        for id in [default_algo, AlgorithmId::FastGm, AlgorithmId::Sharded] {
            engines
                .entry(id)
                .or_insert_with(|| Arc::from(engine::build(id, engine_params)));
        }
        let lsh_params = LshParams::for_threshold(cfg.k, cfg.lsh_threshold);
        let inner = Arc::new(Inner {
            router: Router::new(RouterConfig {
                accel_max_len,
                min_density: 0.25,
                shards: cfg.shards.max(1),
                shard_min_nplus: cfg.shard_min_nplus,
                topk_scan_max: cfg.topk_scan_max,
            }),
            registry: Registry::new(),
            metrics: Metrics::new(),
            batcher,
            lsh: RwLock::new(LshIndex::new(lsh_params)),
            lsh_names: RwLock::new(HashMap::new()),
            store: SketchStore::new(lsh_params, cfg.store_shards.max(1)),
            accel_on,
            default_algo,
            engine_params,
            engines: RwLock::new(engines),
            cfg: cfg.clone(),
        });
        let handler = {
            let inner = inner.clone();
            Arc::new(move |req: Request, ctx: &mut WorkerContext| inner.handle(req, ctx))
        };
        let policy = if cfg.shed { Policy::Shed } else { Policy::Block };
        let pool = WorkerPool::new(cfg.workers, cfg.queue_capacity, policy, handler);
        Ok(Coordinator { inner, pool })
    }

    /// Synchronous request (used by CLI / examples / per-connection loops).
    pub fn call(&self, req: Request) -> Response {
        let op = req.op();
        let t0 = Instant::now();
        if matches!(req, Request::Metrics) {
            self.observe_queue_depth();
        }
        let resp = self.pool.call(req);
        self.inner.metrics.observe(op, t0.elapsed().as_secs_f64());
        resp
    }

    /// Async submit (load generators).
    pub fn submit(&self, req: Request) -> std::sync::mpsc::Receiver<Response> {
        self.inner.metrics.incr(&format!("submit.{}", req.op()));
        if matches!(req, Request::Metrics) {
            self.observe_queue_depth();
        }
        self.pool.submit(req)
    }

    /// Refresh the `queue_depth` gauge from the per-worker queue counters.
    /// The metrics snapshot is the gauge's only consumer, so it is sampled
    /// exactly when a `Request::Metrics` is admitted (the depth the report
    /// will describe) instead of locking the gauge map on every request —
    /// the sketch hot path stays free of metrics-side mutexes.
    fn observe_queue_depth(&self) {
        self.inner.metrics.gauge_set("queue_depth", self.pool.queue_depth() as f64);
    }

    /// Current depth across the per-worker queues.
    pub fn queue_depth(&self) -> u64 {
        self.pool.queue_depth()
    }

    pub fn accel_enabled(&self) -> bool {
        self.inner.accel_on
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Value {
        self.inner.metrics.snapshot()
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.inner.cfg
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
        // inner.batcher shut down on drop of last Arc: explicit drain here.
        match Arc::try_unwrap(self.inner) {
            Ok(inner) => inner.batcher.shutdown(),
            Err(_) => log::warn!("coordinator inner still referenced at shutdown"),
        }
    }
}

impl Inner {
    /// The shared registry sketcher for `id`, built on first use.
    fn engine(&self, id: AlgorithmId) -> Arc<dyn Sketcher> {
        if let Some(e) = self.engines.read().unwrap().get(&id) {
            return e.clone();
        }
        let built: Arc<dyn Sketcher> = Arc::from(engine::build(id, self.engine_params));
        self.engines.write().unwrap().entry(id).or_insert(built).clone()
    }

    /// Sparse sketch through the engine registry. `algo` is the request's
    /// override (validated here — unknown names become error responses);
    /// `None` means the configured default. Plain FastGM may be upgraded to
    /// the §2.3 shard team by the router — identical output either way (the
    /// router only decides parallelism, never the algorithm). The worker's
    /// scratch is reused across requests; `sketch_into` is bit-identical to
    /// a fresh sketch, so reuse is invisible to callers.
    fn sketch_sparse(
        &self,
        v: &SparseVector,
        algo: Option<&str>,
        ctx: &mut WorkerContext,
    ) -> anyhow::Result<GumbelMaxSketch> {
        let id = match algo {
            Some(name) => AlgorithmId::from_name(name)?,
            None => self.default_algo,
        };
        if ctx.scratch.begin_use() {
            self.metrics.incr("scratch.reuse");
        } else {
            self.metrics.incr("scratch.alloc");
        }
        let mut out = GumbelMaxSketch::empty(id.family(), self.cfg.seed, self.cfg.k);
        match self.router.plan_sketch(id, v.n_plus()) {
            SketchPlan::ShardedFastGm => {
                self.metrics.incr("path.sketch.sharded");
                self.engine(AlgorithmId::Sharded).sketch_into(v, &mut ctx.scratch, &mut out);
            }
            SketchPlan::Engine(AlgorithmId::FastGm) => {
                self.metrics.incr("path.sketch.single");
                self.engine(AlgorithmId::FastGm).sketch_into(v, &mut ctx.scratch, &mut out);
            }
            SketchPlan::Engine(other) => {
                self.metrics.incr(&format!("path.sketch.engine.{}", other.name()));
                self.engine(other).sketch_into(v, &mut ctx.scratch, &mut out);
            }
        }
        Ok(out)
    }

    /// LSH banding and the keyed store score candidates with
    /// `estimate_jp`, which is only defined for EXP-register families —
    /// with a `sketch.algo` default of icws / bagminhash / minhash, the
    /// similarity-serving ops (`lsh_insert`, `lsh_query`, `upsert`, `topk`,
    /// `restore`) refuse up front with one clear message instead of
    /// erroring candidate-by-candidate mid-query.
    fn ensure_lsh_capable(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.default_algo.family().has_exponential_registers(),
            "similarity serving (LSH / store top-k) requires an EXP-register default algo \
             (ordered/direct families); configured sketch.algo '{}' is family '{}'",
            self.default_algo.name(),
            self.default_algo.family().name(),
        );
        Ok(())
    }

    /// Refresh the store gauges. Sampled only when a `metrics` request is
    /// served (same policy as `queue_depth`): refreshing after every
    /// upsert/delete would re-scan every shard lock per mutation, purely
    /// to update a gauge only the metrics snapshot reads.
    fn observe_store(&self) {
        self.metrics.gauge_set("store.size", self.store.len() as f64);
        self.metrics.gauge_set("store.lsh_size", self.store.lsh_len() as f64);
    }

    fn handle(&self, req: Request, ctx: &mut WorkerContext) -> Response {
        match self.handle_inner(req, ctx) {
            Ok(resp) => resp,
            Err(e) => {
                self.metrics.incr("errors");
                Response::err(e)
            }
        }
    }

    fn handle_inner(&self, req: Request, ctx: &mut WorkerContext) -> anyhow::Result<Response> {
        Ok(match req {
            Request::Ping => Response::Pong,
            Request::Metrics => {
                self.observe_store();
                let mut snap = self.metrics.snapshot();
                snap.set("sketches", crate::util::json::Value::num(self.registry.sketch_count() as f64));
                snap.set("streams", crate::util::json::Value::num(self.registry.stream_count() as f64));
                snap.set("store", self.store.stats());
                snap.set("accel", crate::util::json::Value::Bool(self.accel_on));
                snap.set("shards", crate::util::json::Value::num(self.cfg.shards as f64));
                snap.set("algo", crate::util::json::Value::str(self.default_algo.name()));
                snap.set(
                    "batch_flushes",
                    crate::util::json::Value::num(
                        self.batcher.flushes.load(std::sync::atomic::Ordering::Relaxed) as f64,
                    ),
                );
                Response::MetricsDump { snapshot: snap }
            }
            Request::Sketch { name, vector, algo } => {
                let sk = self.sketch_sparse(&vector, algo.as_deref(), ctx)?;
                self.registry.put_sketch(&name, sk.clone());
                Response::Sketch { name, sketch: sk }
            }
            Request::SketchDense { name, weights } => {
                // Router decides engine; both produce Direct-family
                // sketches via the batcher (accel or CPU fallback).
                let _path = self.router.route_dense(weights.len());
                let rx = self.batcher.submit(weights);
                let sk = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("batcher dropped request"))??;
                self.registry.put_sketch(&name, sk.clone());
                Response::Sketch { name, sketch: sk }
            }
            Request::GetSketch { name } => {
                let sk = self
                    .registry
                    .get_sketch(&name)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{name}'"))?;
                Response::Sketch { name, sketch: sk }
            }
            Request::Push { stream, items } => {
                let n = self.registry.stream_push(&stream, self.cfg.k, self.cfg.seed, &items);
                Response::Ack { info: format!("stream '{stream}' processed {n}") }
            }
            Request::Cardinality { stream } => {
                let sk = self
                    .registry
                    .stream_sketch(&stream)
                    .ok_or_else(|| anyhow::anyhow!("no stream named '{stream}'"))?;
                Response::Estimate { value: estimate_cardinality(&sk) }
            }
            Request::Jaccard { a, b } => {
                let sa = self
                    .registry
                    .get_sketch(&a)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{a}'"))?;
                let sb = self
                    .registry
                    .get_sketch(&b)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{b}'"))?;
                Response::Estimate { value: estimate_jp(&sa, &sb)? }
            }
            Request::WeightedJaccard { a, b } => {
                let sa = self
                    .registry
                    .get_sketch(&a)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{a}'"))?;
                let sb = self
                    .registry
                    .get_sketch(&b)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{b}'"))?;
                Response::Estimate { value: estimate_weighted_jaccard(&sa, &sb)? }
            }
            Request::Merge { names, out } => {
                anyhow::ensure!(!names.is_empty(), "merge needs at least one sketch");
                let sketches: Vec<_> = names
                    .iter()
                    .map(|n| {
                        self.registry
                            .get_sketch(n)
                            .ok_or_else(|| anyhow::anyhow!("no sketch named '{n}'"))
                    })
                    .collect::<anyhow::Result<_>>()?;
                let merged = merge_tree(&sketches, 4)?;
                self.registry.put_sketch(&out, merged.clone());
                Response::Sketch { name: out, sketch: merged }
            }
            Request::LshInsert { name } => {
                let sk = self
                    .registry
                    .get_sketch(&name)
                    .ok_or_else(|| anyhow::anyhow!("no sketch named '{name}'"))?;
                // LshQuery always sketches the probe with the *default*
                // algo, so an index entry from any other family/seed/k can
                // never legitimately match — reject at insert instead of
                // silently never returning it (or erroring mid-query).
                let want = self.default_algo.family();
                self.ensure_lsh_capable()?;
                anyhow::ensure!(
                    sk.family == want && sk.seed == self.cfg.seed && sk.k() == self.cfg.k,
                    "LSH index accepts only default-algo sketches \
                     (family '{}', seed {}, k {}); '{name}' is family '{}', seed {}, k {}",
                    want.name(),
                    self.cfg.seed,
                    self.cfg.k,
                    sk.family.name(),
                    sk.seed,
                    sk.k(),
                );
                let key = token_id(&name);
                self.lsh.write().unwrap().insert(key, sk);
                self.lsh_names.write().unwrap().insert(key, name.clone());
                Response::Ack { info: format!("indexed '{name}'") }
            }
            Request::LshQuery { vector, limit } => {
                self.ensure_lsh_capable()?;
                let query = self.sketch_sparse(&vector, None, ctx)?;
                let hits = self.lsh.read().unwrap().query(&query, limit)?;
                let names = self.lsh_names.read().unwrap();
                Response::TopK {
                    hits: hits
                        .into_iter()
                        .map(|(key, score)| {
                            (
                                names.get(&key).cloned().unwrap_or_else(|| format!("#{key}")),
                                score,
                            )
                        })
                        .collect(),
                }
            }
            Request::Upsert { key, vector } => {
                // The store is queried with default-algo probes, so every
                // entry is sketched with the default algo — the store can
                // never hold a sketch a `topk` could not score.
                self.ensure_lsh_capable()?;
                // The snapshot codec refuses oversized keys on decode;
                // enforcing the same bound here means every acked upsert
                // is guaranteed snapshot-and-restorable.
                anyhow::ensure!(
                    key.len() <= crate::sketch::codec::MAX_KEY_LEN,
                    "store keys are limited to {} bytes (got {})",
                    crate::sketch::codec::MAX_KEY_LEN,
                    key.len(),
                );
                let sk = self.sketch_sparse(&vector, None, ctx)?;
                self.store.upsert(&key, sk);
                self.metrics.incr("store.upsert");
                Response::Ack { info: format!("upserted '{key}'") }
            }
            Request::Delete { key } => {
                let existed = self.store.delete(&key);
                self.metrics.incr("store.delete");
                Response::Ack {
                    info: if existed {
                        format!("deleted '{key}'")
                    } else {
                        format!("no entry '{key}'")
                    },
                }
            }
            Request::TopK { vector, limit } => {
                self.ensure_lsh_capable()?;
                let query = self.sketch_sparse(&vector, None, ctx)?;
                let (hits, stats) = match self.router.plan_topk(self.store.len()) {
                    TopKPlan::FullScan => {
                        self.metrics.incr("path.topk.scan");
                        self.store.scan_topk(&query, limit)?
                    }
                    TopKPlan::BandProbe => {
                        self.metrics.incr("path.topk.probe");
                        self.store.probe_topk(&query, limit)?
                    }
                };
                self.metrics.add("topk.candidates", stats.candidates as u64);
                self.metrics.add("topk.reranked", stats.reranked as u64);
                Response::TopK { hits }
            }
            Request::StoreStats => Response::Stats { stats: self.store.stats() },
            Request::Snapshot { path } => {
                let (bytes, entries) = self.store.snapshot_bytes();
                // Write-then-rename so a crash or full disk mid-write can
                // never destroy an existing good snapshot at `path`; the
                // temp name is unique per request so concurrent snapshots
                // to the same path cannot interleave into a corrupt file.
                static SNAP_SEQ: std::sync::atomic::AtomicU64 =
                    std::sync::atomic::AtomicU64::new(0);
                let seq = SNAP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let tmp = format!("{path}.tmp.{}.{seq}", std::process::id());
                // write + fsync + rename: without the fsync the rename can
                // survive a crash whose page-cache data did not, replacing
                // the old good snapshot with a truncated file.
                let write_synced = || -> std::io::Result<()> {
                    use std::io::Write as _;
                    let mut f = std::fs::File::create(&tmp)?;
                    f.write_all(&bytes)?;
                    f.sync_all()
                };
                write_synced().map_err(|e| {
                    let _ = std::fs::remove_file(&tmp);
                    anyhow::anyhow!("cannot write snapshot '{tmp}': {e}")
                })?;
                std::fs::rename(&tmp, &path).map_err(|e| {
                    let _ = std::fs::remove_file(&tmp);
                    anyhow::anyhow!("cannot finalize snapshot '{path}': {e}")
                })?;
                self.metrics.incr("store.snapshot");
                Response::Ack {
                    info: format!("snapshot '{path}': {entries} entries, {} bytes", bytes.len()),
                }
            }
            Request::Restore { path } => {
                self.ensure_lsh_capable()?;
                let bytes = std::fs::read(&path)
                    .map_err(|e| anyhow::anyhow!("cannot read snapshot '{path}': {e}"))?;
                let n = self.store.restore_bytes(
                    &bytes,
                    Some((self.default_algo.family(), self.cfg.seed, self.cfg.k)),
                )?;
                self.metrics.incr("store.restore");
                Response::Ack { info: format!("restored {n} entries from '{path}'") }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            k: 128,
            workers: 2,
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    fn vecs() -> (SparseVector, SparseVector) {
        (
            SparseVector::new(vec![1, 2, 3, 4], vec![1.0, 0.5, 2.0, 1.0]),
            SparseVector::new(vec![1, 2, 3, 9], vec![1.0, 0.5, 2.0, 1.5]),
        )
    }

    #[test]
    fn sketch_store_jaccard_flow() {
        let c = coord();
        let (u, v) = vecs();
        let truth = crate::estimate::jaccard::probability_jaccard(&u, &v);
        assert!(matches!(
            c.call(Request::Sketch { name: "u".into(), vector: u, algo: None }),
            Response::Sketch { .. }
        ));
        assert!(matches!(
            c.call(Request::Sketch { name: "v".into(), vector: v, algo: None }),
            Response::Sketch { .. }
        ));
        let Response::Estimate { value } = c.call(Request::Jaccard { a: "u".into(), b: "v".into() })
        else {
            panic!("expected estimate")
        };
        assert!((value - truth).abs() < 0.2, "est={value} truth={truth}");
        c.shutdown();
    }

    #[test]
    fn stream_cardinality_flow() {
        let c = coord();
        let items: Vec<(u64, f64)> = (0..300).map(|i| (i, 1.0)).collect();
        c.call(Request::Push { stream: "s".into(), items: items.clone() });
        c.call(Request::Push { stream: "s".into(), items }); // duplicates
        let Response::Estimate { value } = c.call(Request::Cardinality { stream: "s".into() })
        else {
            panic!("expected estimate")
        };
        assert!((value - 300.0).abs() / 300.0 < 0.25, "est={value}");
        c.shutdown();
    }

    #[test]
    fn dense_sketch_and_family_separation() {
        let c = coord();
        let dense: Vec<f64> = (0..64).map(|i| (i % 7) as f64 * 0.3).collect();
        let Response::Sketch { sketch, .. } =
            c.call(Request::SketchDense { name: "d".into(), weights: dense })
        else {
            panic!("expected sketch")
        };
        assert_eq!(sketch.family, crate::sketch::Family::Direct);
        // Cross-family comparison must error.
        let (u, _) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u, algo: None });
        let resp = c.call(Request::Jaccard { a: "u".into(), b: "d".into() });
        assert!(matches!(resp, Response::Error { .. }), "got {resp:?}");
        c.shutdown();
    }

    #[test]
    fn merge_and_lsh_flow() {
        let c = coord();
        let (u, v) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u.clone(), algo: None });
        c.call(Request::Sketch { name: "v".into(), vector: v, algo: None });
        let Response::Sketch { sketch: merged, .. } =
            c.call(Request::Merge { names: vec!["u".into(), "v".into()], out: "m".into() })
        else {
            panic!("expected merged sketch")
        };
        assert_eq!(merged.k(), 128);
        // LSH: index u and v, query with u — u must be the top hit.
        c.call(Request::LshInsert { name: "u".into() });
        c.call(Request::LshInsert { name: "v".into() });
        let Response::TopK { hits } = c.call(Request::LshQuery { vector: u, limit: 2 }) else {
            panic!("expected topk")
        };
        assert_eq!(hits[0].0, "u");
        assert!((hits[0].1 - 1.0).abs() < 1e-9);
        c.shutdown();
    }

    #[test]
    fn large_sketches_route_through_shards_bit_identically() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 64,
            workers: 2,
            shards: 4,
            shard_min_nplus: 100, // force the sharded path for this vector
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let v = SparseVector::new(
            (0..500u64).map(|i| i * 7 + 1).collect(),
            (0..500).map(|i| 0.1 + (i % 13) as f64 * 0.5).collect(),
        );
        let Response::Sketch { sketch, .. } =
            c.call(Request::Sketch { name: "big".into(), vector: v.clone(), algo: None })
        else {
            panic!("expected sketch")
        };
        // Bit-identical to single-threaded FastGM at the same (k, seed).
        let single = crate::sketch::fastgm::FastGm::new(64, 42).sketch(&v);
        assert_eq!(sketch, single);
        // The sharded path counter must have fired.
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let sharded = snapshot
            .get("counters")
            .and_then(|c| c.get("path.sketch.sharded"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        assert!(sharded >= 1.0, "sharded path not taken: {snapshot}");
        c.shutdown();
    }

    #[test]
    fn algo_field_routes_through_the_engine_registry() {
        let c = coord();
        let (u, _) = vecs();
        // Every registered algorithm is reachable per request.
        for id in AlgorithmId::ALL {
            let Response::Sketch { sketch, .. } = c.call(Request::Sketch {
                name: format!("u-{}", id.name()),
                vector: u.clone(),
                algo: Some(id.name().to_string()),
            }) else {
                panic!("algo {} not served", id.name())
            };
            assert_eq!(sketch.family, id.family(), "family for {}", id.name());
            assert_eq!(sketch.k(), 128);
            // Identical to a direct registry build at the coordinator's
            // (k, seed) — per-worker scratch reuse must be invisible.
            let direct = engine::build(id, EngineParams::new(128, 42).with_shards(4)).sketch(&u);
            assert_eq!(sketch, direct, "engine {} diverged through the service", id.name());
        }
        // Unknown names become error responses listing the registry.
        let resp = c.call(Request::Sketch {
            name: "x".into(),
            vector: u,
            algo: Some("quantum".into()),
        });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("unknown sketch algorithm 'quantum'"), "{message}");
        c.shutdown();
    }

    #[test]
    fn configured_default_algo_is_validated_and_used() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 64,
            workers: 1,
            algo: "pminhash".into(),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, _) = vecs();
        let Response::Sketch { sketch, .. } =
            c.call(Request::Sketch { name: "u".into(), vector: u, algo: None })
        else {
            panic!("expected sketch")
        };
        assert_eq!(sketch.family, crate::sketch::Family::Direct);
        c.shutdown();
        // A bad default fails at construction, not per request.
        assert!(Coordinator::new(CoordinatorConfig {
            algo: "warpdrive".into(),
            ..CoordinatorConfig::default()
        })
        .is_err());
    }

    #[test]
    fn scratch_and_queue_metrics_are_reported() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1, // one worker → second sketch must reuse its scratch
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, v) = vecs();
        c.call(Request::Sketch { name: "u".into(), vector: u, algo: None });
        c.call(Request::Sketch { name: "v".into(), vector: v, algo: None });
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let counter = |name: &str| {
            snapshot
                .get("counters")
                .and_then(|c| c.get(name))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        assert_eq!(counter("scratch.alloc"), 1.0, "{snapshot}");
        assert!(counter("scratch.reuse") >= 1.0, "{snapshot}");
        // queue_depth gauge present (0 once everything drained).
        let depth = snapshot
            .get("gauges")
            .and_then(|g| g.get("queue_depth"))
            .and_then(|v| v.as_f64());
        assert!(depth.is_some(), "queue_depth gauge missing: {snapshot}");
        assert_eq!(c.queue_depth(), 0);
        c.shutdown();
    }

    #[test]
    fn store_upsert_topk_delete_flow() {
        // scan threshold 1 → the second upsert already exercises the probe.
        let c = Coordinator::new(CoordinatorConfig {
            k: 128,
            workers: 2,
            topk_scan_max: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, v) = vecs();
        for (key, vec) in [("u", &u), ("v", &v)] {
            assert!(matches!(
                c.call(Request::Upsert { key: key.into(), vector: vec.clone() }),
                Response::Ack { .. }
            ));
        }
        let Response::TopK { hits } = c.call(Request::TopK { vector: u.clone(), limit: 2 })
        else {
            panic!("expected topk")
        };
        assert_eq!(hits[0].0, "u");
        assert!((hits[0].1 - 1.0).abs() < 1e-9);
        // Stats reflect the two entries.
        let Response::Stats { stats } = c.call(Request::StoreStats) else {
            panic!("expected stats")
        };
        assert_eq!(stats.get("size").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(stats.get("lsh_size").and_then(|v| v.as_f64()), Some(2.0));
        // Delete is idempotent and updates the index.
        let Response::Ack { info } = c.call(Request::Delete { key: "u".into() }) else {
            panic!("expected ack")
        };
        assert!(info.contains("deleted"));
        let Response::Ack { info } = c.call(Request::Delete { key: "u".into() }) else {
            panic!("expected ack")
        };
        assert!(info.contains("no entry"));
        let Response::TopK { hits } = c.call(Request::TopK { vector: u, limit: 2 }) else {
            panic!("expected topk")
        };
        assert!(hits.iter().all(|h| h.0 != "u"), "deleted key still served: {hits:?}");
        // Metrics carry the store gauges and top-k counters.
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let gauge = |name: &str| {
            snapshot.get("gauges").and_then(|g| g.get(name)).and_then(|v| v.as_f64())
        };
        assert_eq!(gauge("store.size"), Some(1.0), "{snapshot}");
        assert_eq!(gauge("store.lsh_size"), Some(1.0), "{snapshot}");
        let counter = |name: &str| {
            snapshot
                .get("counters")
                .and_then(|c| c.get(name))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        assert!(counter("topk.candidates") >= 1.0, "{snapshot}");
        assert!(counter("path.topk.probe") >= 1.0, "{snapshot}");
        c.shutdown();
    }

    #[test]
    fn store_snapshot_restores_across_coordinators() {
        let path = std::env::temp_dir().join(format!(
            "fastgm-service-snap-{}.fgms",
            std::process::id()
        ));
        let path_str = path.to_string_lossy().to_string();
        let cfg = CoordinatorConfig { k: 64, workers: 2, ..CoordinatorConfig::default() };
        let (u, v) = vecs();
        let c = Coordinator::new(cfg.clone()).unwrap();
        c.call(Request::Upsert { key: "u".into(), vector: u.clone() });
        c.call(Request::Upsert { key: "v".into(), vector: v });
        let Response::Ack { info } = c.call(Request::Snapshot { path: path_str.clone() })
        else {
            panic!("expected ack")
        };
        assert!(info.contains("2 entries"), "{info}");
        let Response::TopK { hits: before } =
            c.call(Request::TopK { vector: u.clone(), limit: 2 })
        else {
            panic!("expected topk")
        };
        c.shutdown();

        // A fresh coordinator (cold store) warm-restarts from the snapshot.
        let c2 = Coordinator::new(cfg).unwrap();
        let Response::Ack { info } = c2.call(Request::Restore { path: path_str.clone() })
        else {
            panic!("expected ack, restore failed")
        };
        assert!(info.contains("restored 2 entries"), "{info}");
        let Response::TopK { hits: after } = c2.call(Request::TopK { vector: u, limit: 2 })
        else {
            panic!("expected topk")
        };
        assert_eq!(before, after, "restored store must answer identically");
        // A mismatched config refuses the snapshot cleanly.
        let c3 = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let resp = c3.call(Request::Restore { path: path_str });
        assert!(matches!(resp, Response::Error { .. }), "got {resp:?}");
        c3.shutdown();
        c2.shutdown();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn oversized_store_keys_are_refused_at_upsert() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, _) = vecs();
        let giant = "k".repeat(crate::sketch::codec::MAX_KEY_LEN + 1);
        let resp = c.call(Request::Upsert { key: giant, vector: u.clone() });
        let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
        assert!(message.contains("limited to"), "{message}");
        // At the bound itself, the upsert is accepted and snapshottable.
        let exact = "k".repeat(crate::sketch::codec::MAX_KEY_LEN);
        assert!(matches!(
            c.call(Request::Upsert { key: exact, vector: u }),
            Response::Ack { .. }
        ));
        c.shutdown();
    }

    #[test]
    fn store_ops_refuse_non_race_default_algos() {
        let c = Coordinator::new(CoordinatorConfig {
            k: 32,
            workers: 1,
            algo: "minhash".into(),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let (u, _) = vecs();
        for req in [
            Request::Upsert { key: "u".into(), vector: u.clone() },
            Request::TopK { vector: u, limit: 1 },
            Request::Restore { path: "/nonexistent".into() },
        ] {
            let resp = c.call(req);
            let Response::Error { message } = resp else { panic!("expected error, got {resp:?}") };
            assert!(message.contains("EXP-register"), "{message}");
        }
        c.shutdown();
    }

    #[test]
    fn errors_are_responses_not_panics() {
        let c = coord();
        assert!(matches!(
            c.call(Request::GetSketch { name: "ghost".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Cardinality { stream: "ghost".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Merge { names: vec![], out: "x".into() }),
            Response::Error { .. }
        ));
        // Store persistence I/O failures are error responses too.
        assert!(matches!(
            c.call(Request::Restore { path: "/definitely/not/here.fgms".into() }),
            Response::Error { .. }
        ));
        assert!(matches!(
            c.call(Request::Snapshot { path: "/definitely/not/here/snap.fgms".into() }),
            Response::Error { .. }
        ));
        c.shutdown();
    }

    #[test]
    fn metrics_reflect_traffic() {
        let c = coord();
        c.call(Request::Ping);
        c.call(Request::Ping);
        let Response::MetricsDump { snapshot } = c.call(Request::Metrics) else {
            panic!("expected metrics")
        };
        let pings = snapshot
            .get("counters")
            .and_then(|c| c.get("ops.ping"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(pings >= 2.0);
        c.shutdown();
    }
}
