//! Focused probe for the §Perf iteration loop (small, fast, targeted).
//! Reports the parallel shard-merge path next to single-threaded FastGM so
//! the multi-core speedup (and the small-n regression region the router's
//! `shard_min_nplus` threshold guards against) is visible per run, plus the
//! engine's scratch-reuse path next to fresh-allocation sketching so the
//! zero-allocation win is measured on every run.
//!
//! `cargo bench --bench perf_probe -- --json BENCH_perf.json` additionally
//! writes a machine-readable summary (name → ns/op + ops/s) so runs
//! accumulate a diffable perf trajectory; default stdout output is
//! unchanged.
use fastgm::data::synthetic::{dense_vector, WeightDist};
use fastgm::data::stream::generate;
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::lemiesz::LemieszSketch;
use fastgm::sketch::pminhash::PMinHash;
use fastgm::sketch::sharded::ShardedSketcher;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::sketch::{Family, GumbelMaxSketch, SketchScratch, Sketcher};
use fastgm::util::bench::{Bencher, Suite};
use fastgm::util::rng::SplitMix64;

/// `--json <path>` / `--json=<path>` from the post-`--` bench args.
/// A `--json` with no path is an error, not a silent no-op — the caller
/// asked for a summary file and must not discover at diff time that none
/// was ever written.
fn json_path(argv: &[String]) -> Result<Option<String>, String> {
    let mut it = argv.iter();
    while let Some(arg) = it.next() {
        if arg == "--json" {
            return match it.next() {
                Some(path) => Ok(Some(path.clone())),
                None => Err("--json requires a path (e.g. --json BENCH_perf.json)".into()),
            };
        }
        if let Some(path) = arg.strip_prefix("--json=") {
            return Ok(Some(path.to_string()));
        }
    }
    Ok(None)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json = match json_path(&argv) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let b = Bencher { budget: 0.6, samples: 9, warmup: 0.08 };
    let mut suite = Suite::new();
    let mut rng = SplitMix64::new(42);
    for (n, k) in [(1000usize, 64usize), (100, 256), (1000, 256), (1000, 1024), (10_000, 1024)] {
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        let fg = FastGm::new(k, 1);
        suite.record(b.run(&format!("fastgm/n{n}/k{k}"), || fg.sketch(&v)));
        for shards in [2usize, 4] {
            let sh = ShardedSketcher::new(k, 1, shards);
            suite.record(b.run(&format!("sharded{shards}/n{n}/k{k}"), || sh.sketch(&v)));
        }
        let pm = PMinHash::new(k, 1);
        suite.record(b.run(&format!("pminhash/n{n}/k{k}"), || pm.sketch(&v)));
    }
    // The shard team's home turf: a large sparse vector (n⁺ ≫ P·k·ln k).
    {
        let (n, k) = (200_000usize, 1024usize);
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        let fg = FastGm::new(k, 1);
        suite.record(b.run(&format!("fastgm/n{n}/k{k}"), || fg.sketch(&v)));
        for shards in [2usize, 4, 8] {
            let sh = ShardedSketcher::new(k, 1, shards);
            suite.record(b.run(&format!("sharded{shards}/n{n}/k{k}"), || sh.sketch(&v)));
        }
        if let Some(sp) = suite.speedup(&format!("fastgm/n{n}/k{k}"), &format!("sharded4/n{n}/k{k}")) {
            println!("  -> sharded(4) speedup over fastgm at n={n}, k={k}: {sp:.2}x");
        }
    }
    // Engine scratch reuse vs fresh allocation: the same FastGm, one path
    // reusing a per-caller SketchScratch + output registers (the
    // coordinator's per-worker serving path), the other allocating
    // everything per call. Outputs are bit-identical (engine_props.rs);
    // the delta below is pure allocation/initialization cost.
    for (n, k) in [(1000usize, 256usize), (10_000, 1024)] {
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        let fg = FastGm::new(k, 1);
        let mut scratch = SketchScratch::new();
        let mut out = GumbelMaxSketch::empty(Family::Ordered, 1, k);
        suite.record(b.run(&format!("engine-reuse/fastgm/n{n}/k{k}"), || {
            fg.sketch_into(&v, &mut scratch, &mut out);
            out.y[0]
        }));
        suite.record(b.run(&format!("engine-fresh/fastgm/n{n}/k{k}"), || fg.sketch(&v)));
        if let Some(sp) = suite.speedup(
            &format!("engine-fresh/fastgm/n{n}/k{k}"),
            &format!("engine-reuse/fastgm/n{n}/k{k}"),
        ) {
            println!("  -> scratch-reuse speedup over fresh alloc at n={n}, k={k}: {sp:.2}x");
        }
    }

    // Cluster routing hot path: every upsert/delete/push/gather computes
    // HRW owners. The Partitioner hashes each node-id string exactly once
    // at construction and only mixes the precomputed 64-bit digests per
    // call; `cluster.owner_naive_ns` is the rehash-per-call strawman
    // (token_id over every node-id string on every owner() call) that a
    // straightforward implementation would ship, kept here so the win
    // stays visible in every `--json` summary.
    {
        use fastgm::coordinator::cluster::Partitioner;
        use fastgm::util::hash::{mix2, token_id};
        let node_ids: Vec<String> = (0..8).map(|i| format!("site-{i}")).collect();
        let p = Partitioner::new(&node_ids).unwrap();
        let keys: Vec<String> = (0..256).map(|i| format!("doc{i:05}")).collect();
        let mut at = 0usize;
        suite.record(b.run("cluster.owner_ns", || {
            at = (at + 1) % keys.len();
            p.owner(&keys[at])
        }));
        let naive_owner = |key: &str| -> usize {
            let id = token_id(key);
            let mut best = 0usize;
            let mut best_w = u64::MIN;
            for (i, node) in node_ids.iter().enumerate() {
                let w = mix2(token_id(node), id);
                if i == 0 || w > best_w {
                    best = i;
                    best_w = w;
                }
            }
            best
        };
        let mut at2 = 0usize;
        suite.record(b.run("cluster.owner_naive_ns", || {
            at2 = (at2 + 1) % keys.len();
            naive_owner(&keys[at2])
        }));
        // Replica sets pay a small top-R selection on top of the mixes.
        let mut at3 = 0usize;
        suite.record(b.run("cluster.owners_r2_ns", || {
            at3 = (at3 + 1) % keys.len();
            p.owners(&keys[at3], 2)
        }));
        if let Some(sp) = suite.speedup("cluster.owner_naive_ns", "cluster.owner_ns") {
            println!("  -> precomputed node digests vs rehash-per-call at 8 nodes: {sp:.2}x");
        }
    }

    let stream = generate(&mut rng, 1000, 1.0, WeightDist::Uniform01, 0);
    for k in [256usize, 1024] {
        suite.record(b.run(&format!("stream-fastgm/n1000/k{k}"), || {
            let mut s = StreamFastGm::new(k, 1);
            for &(id, w) in &stream.events { s.push(id, w); }
            s.sketch()
        }));
        suite.record(b.run(&format!("lemiesz/n1000/k{k}"), || {
            let mut s = LemieszSketch::new(k, 1);
            for &(id, w) in &stream.events { s.push(id, w); }
            s.sketch()
        }));
    }

    if let Some(path) = json {
        match suite.write_json(&path) {
            Ok(()) => println!("  -> wrote {} results to {path}", suite.results.len()),
            Err(e) => {
                eprintln!("cannot write bench summary '{path}': {e}");
                std::process::exit(1);
            }
        }
    }
}
