//! Focused probe for the §Perf iteration loop (small, fast, targeted).
//! Reports the parallel shard-merge path next to single-threaded FastGM so
//! the multi-core speedup (and the small-n regression region the router's
//! `shard_min_nplus` threshold guards against) is visible per run, plus the
//! engine's scratch-reuse path next to fresh-allocation sketching so the
//! zero-allocation win is measured on every run.
//!
//! `cargo bench --bench perf_probe -- --json BENCH_perf.json` additionally
//! writes a machine-readable summary (name → ns/op + ops/s) so runs
//! accumulate a diffable perf trajectory; default stdout output is
//! unchanged. `FASTGM_BENCH_BUDGET` (seconds per benchmark) tunes the
//! wall-clock budget — CI uses a small value, local runs the default.
//!
//! The `kernel.*` and `sketch.*` probes come in scalar-vs-SIMD pairs
//! (`<name>_scalar_ns` vs `<name>_ns`) via `kernels::set_forced`; because
//! the backends are bit-identical, forcing is purely a measurement knob.
use fastgm::data::synthetic::{dense_vector, WeightDist};
use fastgm::data::stream::generate;
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::kernels::{self, Backend};
use fastgm::sketch::lemiesz::LemieszSketch;
use fastgm::sketch::pminhash::PMinHash;
use fastgm::sketch::sharded::ShardedSketcher;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::sketch::{Family, GumbelMaxSketch, SketchScratch, Sketcher};
use fastgm::util::bench::{Bencher, Suite};
use fastgm::util::rng::{direct_element_hash, SplitMix64};

/// `--json <path>` / `--json=<path>` from the post-`--` bench args.
/// A `--json` with no path is an error, not a silent no-op — the caller
/// asked for a summary file and must not discover at diff time that none
/// was ever written.
fn json_path(argv: &[String]) -> Result<Option<String>, String> {
    let mut it = argv.iter();
    while let Some(arg) = it.next() {
        if arg == "--json" {
            return match it.next() {
                Some(path) => Ok(Some(path.clone())),
                None => Err("--json requires a path (e.g. --json BENCH_perf.json)".into()),
            };
        }
        if let Some(path) = arg.strip_prefix("--json=") {
            return Ok(Some(path.to_string()));
        }
    }
    Ok(None)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json = match json_path(&argv) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let mut b = Bencher { budget: 0.6, samples: 9, warmup: 0.08 };
    if let Ok(s) = std::env::var("FASTGM_BENCH_BUDGET") {
        if let Ok(x) = s.parse::<f64>() {
            b.budget = x.max(0.05);
        }
    }
    let mut suite = Suite::new();
    let mut rng = SplitMix64::new(42);
    for (n, k) in [(1000usize, 64usize), (100, 256), (1000, 256), (1000, 1024), (10_000, 1024)] {
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        let fg = FastGm::new(k, 1);
        suite.record(b.run(&format!("fastgm/n{n}/k{k}"), || fg.sketch(&v)));
        for shards in [2usize, 4] {
            let sh = ShardedSketcher::new(k, 1, shards);
            suite.record(b.run(&format!("sharded{shards}/n{n}/k{k}"), || sh.sketch(&v)));
        }
        let pm = PMinHash::new(k, 1);
        suite.record(b.run(&format!("pminhash/n{n}/k{k}"), || pm.sketch(&v)));
    }
    // The shard team's home turf: a large sparse vector (n⁺ ≫ P·k·ln k).
    {
        let (n, k) = (200_000usize, 1024usize);
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        let fg = FastGm::new(k, 1);
        suite.record(b.run(&format!("fastgm/n{n}/k{k}"), || fg.sketch(&v)));
        for shards in [2usize, 4, 8] {
            let sh = ShardedSketcher::new(k, 1, shards);
            suite.record(b.run(&format!("sharded{shards}/n{n}/k{k}"), || sh.sketch(&v)));
        }
        if let Some(sp) = suite.speedup(&format!("fastgm/n{n}/k{k}"), &format!("sharded4/n{n}/k{k}")) {
            println!("  -> sharded(4) speedup over fastgm at n={n}, k={k}: {sp:.2}x");
        }
    }
    // Engine scratch reuse vs fresh allocation: the same FastGm, one path
    // reusing a per-caller SketchScratch + output registers (the
    // coordinator's per-worker serving path), the other allocating
    // everything per call. Outputs are bit-identical (engine_props.rs);
    // the delta below is pure allocation/initialization cost.
    for (n, k) in [(1000usize, 256usize), (10_000, 1024)] {
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        let fg = FastGm::new(k, 1);
        let mut scratch = SketchScratch::new();
        let mut out = GumbelMaxSketch::empty(Family::Ordered, 1, k);
        suite.record(b.run(&format!("engine-reuse/fastgm/n{n}/k{k}"), || {
            fg.sketch_into(&v, &mut scratch, &mut out);
            out.y[0]
        }));
        suite.record(b.run(&format!("engine-fresh/fastgm/n{n}/k{k}"), || fg.sketch(&v)));
        if let Some(sp) = suite.speedup(
            &format!("engine-fresh/fastgm/n{n}/k{k}"),
            &format!("engine-reuse/fastgm/n{n}/k{k}"),
        ) {
            println!("  -> scratch-reuse speedup over fresh alloc at n={n}, k={k}: {sp:.2}x");
        }
    }

    // Cluster routing hot path: every upsert/delete/push/gather computes
    // HRW owners. The Partitioner hashes each node-id string exactly once
    // at construction and only mixes the precomputed 64-bit digests per
    // call; `cluster.owner_naive_ns` is the rehash-per-call strawman
    // (token_id over every node-id string on every owner() call) that a
    // straightforward implementation would ship, kept here so the win
    // stays visible in every `--json` summary.
    {
        use fastgm::coordinator::cluster::Partitioner;
        use fastgm::util::hash::{mix2, token_id};
        let node_ids: Vec<String> = (0..8).map(|i| format!("site-{i}")).collect();
        let p = Partitioner::new(&node_ids).unwrap();
        let keys: Vec<String> = (0..256).map(|i| format!("doc{i:05}")).collect();
        let mut at = 0usize;
        suite.record(b.run("cluster.owner_ns", || {
            at = (at + 1) % keys.len();
            p.owner(&keys[at])
        }));
        let naive_owner = |key: &str| -> usize {
            let id = token_id(key);
            let mut best = 0usize;
            let mut best_w = u64::MIN;
            for (i, node) in node_ids.iter().enumerate() {
                let w = mix2(token_id(node), id);
                if i == 0 || w > best_w {
                    best = i;
                    best_w = w;
                }
            }
            best
        };
        let mut at2 = 0usize;
        suite.record(b.run("cluster.owner_naive_ns", || {
            at2 = (at2 + 1) % keys.len();
            naive_owner(&keys[at2])
        }));
        // Replica sets pay a small top-R selection on top of the mixes.
        let mut at3 = 0usize;
        suite.record(b.run("cluster.owners_r2_ns", || {
            at3 = (at3 + 1) % keys.len();
            p.owners(&keys[at3], 2)
        }));
        if let Some(sp) = suite.speedup("cluster.owner_naive_ns", "cluster.owner_ns") {
            println!("  -> precomputed node digests vs rehash-per-call at 8 nodes: {sp:.2}x");
        }
    }

    let stream = generate(&mut rng, 1000, 1.0, WeightDist::Uniform01, 0);
    for k in [256usize, 1024] {
        suite.record(b.run(&format!("stream-fastgm/n1000/k{k}"), || {
            let mut s = StreamFastGm::new(k, 1);
            for &(id, w) in &stream.events { s.push(id, w); }
            s.sketch()
        }));
        suite.record(b.run(&format!("lemiesz/n1000/k{k}"), || {
            let mut s = LemieszSketch::new(k, 1);
            for &(id, w) in &stream.events { s.push(id, w); }
            s.sketch()
        }));
    }

    // Query-engine sampling probes (ISSUE 8): serving `sample` is one scan
    // of the k registers plus O(1) uniform draws — independent of the
    // ingested vector's size — and `partition` is one pass over the y
    // registers. The union probe adds the §2.3 merges the store's
    // multi-key target pays before drawing.
    {
        use fastgm::estimate::sample;
        let v = dense_vector(&mut rng, 10_000, WeightDist::Uniform01);
        for k in [256usize, 1024] {
            let sk = FastGm::new(k, 1).sketch(&v);
            let mut seed = 0u64;
            suite.record(b.run(&format!("sample.draw32_k{k}_ns"), || {
                seed = seed.wrapping_add(1);
                sample::sample_n(&sk, 32, seed).unwrap()
            }));
            suite.record(b.run(&format!("partition.total_weight_k{k}_ns"), || {
                sample::total_weight(&sk).unwrap()
            }));
        }
        let parts: Vec<GumbelMaxSketch> = (0..8)
            .map(|_| {
                // Distinct vectors (the rng advances), one shared sketch
                // seed so the parts are mergeable.
                let pv = dense_vector(&mut rng, 2000, WeightDist::Uniform01);
                FastGm::new(256, 1).sketch(&pv)
            })
            .collect();
        let refs: Vec<&GumbelMaxSketch> = parts.iter().collect();
        let mut seed = 0u64;
        suite.record(b.run("sample.union8_k256_ns", || {
            seed = seed.wrapping_add(1);
            sample::sample_union(&refs, 32, seed).unwrap()
        }));
    }

    // Read-path cache probes (ISSUE 9): the node's merged-union cache next
    // to the §2.3 re-merge a hit elides, plus the top-k result cache. Hit
    // and miss run the IDENTICAL request through `Node::execute_alloc` —
    // the miss node simply has the cache disabled — so the delta is
    // exactly the work a validated hit skips (answers are bit-identical
    // either way; node.rs property tests pin that).
    {
        use fastgm::coordinator::node::Node;
        use fastgm::coordinator::protocol::{QueryTarget, Request, Response};
        use fastgm::coordinator::service::CoordinatorConfig;

        let mk_node = |cache_enabled: bool| {
            Node::new(CoordinatorConfig {
                k: 256,
                seed: 1,
                node_id: "bench".into(),
                cache_enabled,
                ..Default::default()
            })
            .unwrap()
        };
        let hot = mk_node(true);
        let cold = mk_node(false);
        let mut r2 = SplitMix64::new(17);
        let keys: Vec<String> = (0..32).map(|i| format!("doc{i:03}")).collect();
        for key in &keys {
            let v = dense_vector(&mut r2, 500, WeightDist::Uniform01);
            for node in [&hot, &cold] {
                let resp = node.execute_alloc(Request::Upsert {
                    key: key.clone(),
                    vector: v.clone(),
                    version: None,
                });
                assert!(matches!(resp, Response::Ack { .. }), "{resp:?}");
            }
        }
        let target = QueryTarget::Keys(keys.clone());
        let mut seed = 0u64;
        suite.record(b.run("cache.merge_keys_hit_ns", || {
            seed = seed.wrapping_add(1);
            hot.execute_alloc(Request::Sample { target: target.clone(), n: 16, seed })
        }));
        let mut seed = 0u64;
        suite.record(b.run("cache.merge_keys_miss_ns", || {
            seed = seed.wrapping_add(1);
            cold.execute_alloc(Request::Sample { target: target.clone(), n: 16, seed })
        }));
        let qv = dense_vector(&mut r2, 200, WeightDist::Uniform01);
        suite.record(b.run("cache.topk_hit_ns", || {
            hot.execute_alloc(Request::TopK { vector: qv.clone(), limit: 5 })
        }));
        if let Some(sp) = suite.speedup("cache.merge_keys_miss_ns", "cache.merge_keys_hit_ns") {
            println!("  -> merged-union cache hit speedup over a 32-key re-merge: {sp:.2}x");
        }
    }

    // Cluster gather warm-vs-cold (ISSUE 9 tentpole): the same scatter-
    // gather `topk` against a live 2-node local cluster, once through an
    // uncached client (every candidate blob re-fetched and re-decoded per
    // gather) and once through a client whose (key, version) gather-blob
    // cache is warm (one `store_keys` version walk, zero blob fetches).
    {
        use fastgm::coordinator::cluster::{ClusterClient, LocalCluster, ReplicaConfig};
        use fastgm::coordinator::service::CoordinatorConfig;

        let ccfg = CoordinatorConfig {
            k: 256,
            seed: 1,
            workers: 2,
            node_id: "bench".into(),
            topk_scan_max: 100_000,
            ..Default::default()
        };
        let cluster = LocalCluster::start(2, &ccfg).unwrap();
        let mut cold_cc = ClusterClient::connect(&cluster.addrs()).unwrap();
        let mut warm_cc = ClusterClient::connect_with(
            &cluster.addrs(),
            ReplicaConfig { cache_bytes: 8 << 20, ..Default::default() },
        )
        .unwrap();
        let mut r3 = SplitMix64::new(23);
        for i in 0..64 {
            let v = dense_vector(&mut r3, 200, WeightDist::Uniform01);
            cold_cc.upsert(&format!("doc{i:03}"), v).unwrap();
        }
        let q = dense_vector(&mut r3, 200, WeightDist::Uniform01);
        warm_cc.topk(&q, 8).unwrap(); // fill the gather cache
        suite.record(b.run("cluster.gather_cold_ns", || cold_cc.topk(&q, 8).unwrap()));
        suite.record(b.run("cluster.gather_warm_ns", || warm_cc.topk(&q, 8).unwrap()));
        if let Some(sp) = suite.speedup("cluster.gather_cold_ns", "cluster.gather_warm_ns") {
            println!("  -> warm (key,version) gather speedup over cold blob fetches: {sp:.2}x");
        }
        cluster.stop();
    }

    // Binary blob data plane (ISSUE 10): `blob.decode_{copy,view}_ns`
    // isolate the zero-copy read path — the SAME k=1024 `sketch_blob_bin`
    // frame decoded by materializing an owned Response (payload memcpy'd
    // out of the input buffer into a fresh Vec) vs through the borrowing
    // `FrameView` (registers sliced in place and fed straight to
    // `codec::decode_sketch_bytes`). Both verify the same checksum and
    // build the same sketch; the delta is the copy.
    {
        use fastgm::coordinator::frame::{self, FrameMsg, FrameStatus, FrameViewStatus};
        use fastgm::coordinator::protocol::Response;
        use fastgm::sketch::codec;

        let v = dense_vector(&mut rng, 10_000, WeightDist::Uniform01);
        let sk = FastGm::new(1024, 1).sketch(&v);
        let blob = codec::encode_sketch_bytes("doc-bulk", 7, &sk);
        let mut frame_bytes = Vec::new();
        frame::encode_response_frame(
            5,
            &Response::SketchBlobBin { name: "doc-bulk".into(), data: blob },
            &mut frame_bytes,
        );
        suite.record(b.run("blob.decode_copy_ns", || {
            let FrameStatus::Frame { msg, .. } = frame::decode_frame(&frame_bytes).unwrap()
            else {
                panic!("bench frame incomplete")
            };
            let FrameMsg::Response(Response::SketchBlobBin { data, .. }) = msg else {
                panic!("bench frame is not a blob")
            };
            codec::decode_sketch_bytes(&data).unwrap().1
        }));
        suite.record(b.run("blob.decode_view_ns", || {
            let FrameViewStatus::Frame(view) = frame::decode_frame_view(&frame_bytes).unwrap()
            else {
                panic!("bench frame incomplete")
            };
            let (_, bytes) = view.sketch_blob_bin().unwrap().expect("blob frame");
            codec::decode_sketch_bytes(bytes).unwrap().1
        }));
        if let Some(sp) = suite.speedup("blob.decode_copy_ns", "blob.decode_view_ns") {
            println!("  -> zero-copy view decode speedup over owned decode at k=1024: {sp:.2}x");
        }
    }

    // Live blob transfer (ISSUE 10 tentpole): one event-server node holds
    // a k=1024 document; `blob.fetch_hex_ns` pulls it as a hex-in-JSON
    // `sketch_blob` line, `blob.fetch_binary_ns` pulls the SAME blob as a
    // `sketch_blob_bin` frame — raw codec bytes spliced into the server's
    // vectored write, zero-copy view decode on the client. Same socket
    // machinery, same sketch; the delta is the data plane.
    #[cfg(unix)]
    {
        use fastgm::coordinator::client::Client;
        use fastgm::coordinator::event_server::EventServer;
        use fastgm::coordinator::protocol::SketchSource;
        use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
        use std::sync::Arc;

        let cfg = CoordinatorConfig {
            k: 1024,
            seed: 1,
            workers: 2,
            node_id: "bench".into(),
            ..Default::default()
        };
        let coord = Arc::new(Coordinator::new(cfg).unwrap());
        let es = EventServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        let addr = es.addr.to_string();
        let mut ingest = Client::connect(&addr).unwrap();
        let v = dense_vector(&mut rng, 10_000, WeightDist::Uniform01);
        ingest.upsert("doc-bulk", v).unwrap();
        let mut hex_c = Client::connect(&addr).unwrap();
        let mut bin_c = Client::connect_framed(&addr).unwrap();
        suite.record(b.run("blob.fetch_hex_ns", || {
            hex_c.sketch_fetch("doc-bulk", SketchSource::Store).unwrap()
        }));
        suite.record(b.run("blob.fetch_binary_ns", || {
            bin_c.sketch_fetch_bin("doc-bulk", SketchSource::Store).unwrap()
        }));
        if let Some(sp) = suite.speedup("blob.fetch_hex_ns", "blob.fetch_binary_ns") {
            println!("  -> binary blob fetch speedup over hex-in-JSON at k=1024: {sp:.2}x");
        }
        drop((ingest, hex_c, bin_c));
        es.stop();
        Arc::try_unwrap(coord).ok().expect("event server released the coordinator").shutdown();
    }

    // Cluster repair over each data plane (ISSUE 10): the same converged
    // 2-node event cluster at R=2 walked by `repair` — phase-1 version
    // walk plus phase-3 stream-sketch fetch/merge/install on every node —
    // once through a hex-in-JSON client and once through a framed one,
    // where every fetch and install rides `*_bin` frames with the blob
    // encoded once per fan-out.
    #[cfg(unix)]
    {
        use fastgm::coordinator::cluster::{ClusterClient, LocalCluster, ReplicaConfig};
        use fastgm::coordinator::service::CoordinatorConfig;

        let ccfg = CoordinatorConfig {
            k: 1024,
            seed: 1,
            workers: 2,
            node_id: "bench".into(),
            topk_scan_max: 100_000,
            ..Default::default()
        };
        let cluster = LocalCluster::start_event(2, &ccfg).unwrap();
        let mut hex_cc = ClusterClient::connect_with(
            &cluster.addrs(),
            ReplicaConfig { replication: 2, write_quorum: 1, ..Default::default() },
        )
        .unwrap();
        let mut bin_cc = ClusterClient::connect_with(
            &cluster.addrs(),
            ReplicaConfig {
                replication: 2,
                write_quorum: 1,
                framed: true,
                ..Default::default()
            },
        )
        .unwrap();
        let mut r4 = SplitMix64::new(29);
        for i in 0..24 {
            let v = dense_vector(&mut r4, 500, WeightDist::Uniform01);
            bin_cc.upsert(&format!("doc{i:03}"), v).unwrap();
        }
        let items: Vec<(u64, f64)> = (0..2000u64).map(|i| (i * 31 + 7, 1.0)).collect();
        bin_cc.push("pkts", &items).unwrap();
        let streams = ["pkts".to_string()];
        suite.record(b.run("cluster.repair_hex_ns", || hex_cc.repair(&streams).unwrap()));
        suite.record(b.run("cluster.repair_binary_ns", || bin_cc.repair(&streams).unwrap()));
        if let Some(sp) = suite.speedup("cluster.repair_hex_ns", "cluster.repair_binary_ns") {
            println!("  -> binary-plane repair speedup over hex at k=1024: {sp:.2}x");
        }
        cluster.stop();
    }

    // Kernel-level scalar-vs-SIMD pairs: the same kernel, forced onto each
    // backend. `<name>_scalar_ns` is the baseline; `<name>_ns` is whatever
    // the host's best backend delivers (scalar again on non-AVX2 hosts, so
    // the pair degenerates to noise there rather than lying).
    {
        let k = 1024usize;
        let mut r = SplitMix64::new(7);
        let ys: Vec<f64> = (0..k).map(|_| r.next_exp()).collect();
        let oy: Vec<f64> = (0..k).map(|_| r.next_exp()).collect();
        let os: Vec<u64> = (0..k).map(|_| r.next_u64()).collect();
        let sa: Vec<u64> = (0..k).map(|_| r.next_range(0, 50) as u64).collect();
        let sb: Vec<u64> = (0..k).map(|_| r.next_range(0, 50) as u64).collect();
        let h = direct_element_hash(42, 7);
        for (suffix, backend) in [("_scalar", Backend::Scalar), ("", kernels::detected())] {
            let mut stream_rng = SplitMix64::new(1);
            let mut buf = vec![0.0f64; k];
            suite.record(b.run(&format!("kernel.uniform_batch{suffix}_ns"), || {
                kernels::fill_uniform_block_with(backend, &mut stream_rng, &mut buf);
                buf[0]
            }));
            let mut stream_rng2 = SplitMix64::new(1);
            suite.record(b.run(&format!("kernel.gumbel_batch{suffix}_ns"), || {
                kernels::fill_exp_block_with(backend, &mut stream_rng2, &mut buf);
                buf[0]
            }));
            suite.record(b.run(&format!("kernel.argmin{suffix}_ns"), || {
                kernels::argmin_f64_with(backend, &ys)
            }));
            let mut my = ys.clone();
            let mut ms = os.clone();
            suite.record(b.run(&format!("kernel.merge{suffix}_ns"), || {
                kernels::merge_min_into_with(backend, &mut my, &mut ms, &oy, &os);
                my[0]
            }));
            suite.record(b.run(&format!("kernel.match{suffix}_ns"), || {
                kernels::match_count_with(backend, &sa, &sb)
            }));
            let mut row = vec![0.0f32; k];
            suite.record(b.run(&format!("kernel.direct_row{suffix}_ns"), || {
                kernels::direct_exp_row_with(backend, h, 0, &mut row);
                row[0]
            }));
        }
        for name in [
            "kernel.uniform_batch",
            "kernel.gumbel_batch",
            "kernel.argmin",
            "kernel.merge",
            "kernel.match",
            "kernel.direct_row",
        ] {
            if let Some(sp) = suite.speedup(&format!("{name}_scalar_ns"), &format!("{name}_ns")) {
                println!("  -> {name} SIMD speedup: {sp:.2}x");
            }
        }
    }

    // End-to-end sketch pairs under a forced backend: what the kernel wins
    // buy at the algorithm level. `set_forced` is a process-global
    // measurement knob (backends are bit-identical), reset afterwards.
    {
        let v_ord = dense_vector(&mut rng, 10_000, WeightDist::Uniform01);
        let v_dir = dense_vector(&mut rng, 1000, WeightDist::Uniform01);
        let fg = FastGm::new(1024, 1);
        let pm = PMinHash::new(256, 1);
        for (suffix, backend) in [("_scalar", Backend::Scalar), ("", kernels::detected())] {
            kernels::set_forced(Some(backend));
            suite.record(b.run(&format!("sketch.fastgm{suffix}_ns"), || fg.sketch(&v_ord)));
            suite.record(b.run(&format!("sketch.pminhash{suffix}_ns"), || pm.sketch(&v_dir)));
        }
        kernels::set_forced(None);
        for name in ["sketch.fastgm", "sketch.pminhash"] {
            if let Some(sp) = suite.speedup(&format!("{name}_scalar_ns"), &format!("{name}_ns")) {
                println!("  -> {name} end-to-end SIMD speedup: {sp:.2}x");
            }
        }
    }

    // Frame-vs-JSON wire codec pairs (ISSUE 7): the same typed message
    // encoded/decoded through the binary frame body codec and through the
    // JSON line protocol. Bodies only (no socket) — this isolates the
    // serialization cost the framed transport removes from every request.
    {
        use fastgm::coordinator::frame;
        use fastgm::coordinator::protocol::{self, Request, Response};

        let vec64 = dense_vector(&mut rng, 64, WeightDist::Uniform01);
        let req = Request::Upsert { key: "doc-00042".into(), vector: vec64, version: None };
        let resp = Response::TopK {
            hits: (0..10).map(|i| (format!("doc{i:04}"), 0.5 + i as f64 / 100.0)).collect(),
        };
        let mut scratch = Vec::new();
        suite.record(b.run("frame.encode_request_ns", || {
            scratch.clear();
            frame::encode_request_body(&req, &mut scratch);
            scratch.len()
        }));
        suite.record(b.run("frame.encode_request_json_ns", || {
            protocol::encode_line(&req.to_json()).len()
        }));
        let mut body = Vec::new();
        frame::encode_request_body(&req, &mut body);
        let line = protocol::encode_line(&req.to_json());
        suite.record(b.run("frame.decode_request_ns", || {
            frame::decode_request_body(&body).unwrap()
        }));
        suite.record(b.run("frame.decode_request_json_ns", || {
            protocol::decode_request(&line).unwrap()
        }));
        let mut rscratch = Vec::new();
        suite.record(b.run("frame.encode_response_ns", || {
            rscratch.clear();
            frame::encode_response_body(&resp, &mut rscratch);
            rscratch.len()
        }));
        suite.record(b.run("frame.encode_response_json_ns", || {
            protocol::encode_line(&resp.to_json()).len()
        }));
        let mut rbody = Vec::new();
        frame::encode_response_body(&resp, &mut rbody);
        let rline = protocol::encode_line(&resp.to_json());
        suite.record(b.run("frame.decode_response_ns", || {
            frame::decode_response_body(&rbody).unwrap()
        }));
        suite.record(b.run("frame.decode_response_json_ns", || {
            protocol::decode_response(&rline).unwrap()
        }));
        for side in ["request", "response"] {
            for dir in ["encode", "decode"] {
                let (json_n, bin_n) =
                    (format!("frame.{dir}_{side}_json_ns"), format!("frame.{dir}_{side}_ns"));
                if let Some(sp) = suite.speedup(&json_n, &bin_n) {
                    println!("  -> binary {dir} {side} speedup over JSON: {sp:.2}x");
                }
            }
        }
        println!(
            "  -> wire bytes per upsert: binary {} vs JSON {}",
            body.len() + frame::HEADER_LEN + 8,
            line.len()
        );
    }

    // Transport saturation (ISSUE 7 acceptance): C pipelining clients ×
    // P in-flight pings, sustained — the event-driven framed transport
    // against the thread-per-connection JSON-lines server. `..._ns` is
    // wall-clock per request at saturation (ops_per_s in the JSON summary
    // is the sustained req/s); `..._p99_ns` is the p99 per-request
    // latency. Scale shrinks under a small FASTGM_BENCH_BUDGET so the CI
    // smoke run stays fast while exercising the identical code path.
    #[cfg(unix)]
    {
        use fastgm::coordinator::client::Client;
        use fastgm::coordinator::event_server::EventServer;
        use fastgm::coordinator::protocol::{Request, Response};
        use fastgm::coordinator::server::Server;
        use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
        use fastgm::util::bench::BenchResult;
        use fastgm::util::stats::percentile;
        use std::sync::Arc;

        let smoke = b.budget <= 0.15;
        let (clients, pipeline, rounds) = if smoke { (4usize, 16usize, 10usize) } else { (8, 64, 50) };

        // (per-request latency samples, wall seconds, total requests)
        let saturate = |addr: String, framed: bool| -> (Vec<f64>, f64, u64) {
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for _ in 0..clients {
                let addr = addr.clone();
                handles.push(std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).expect("saturation client connect");
                    if framed {
                        c.set_framed(true).expect("framed upgrade");
                    }
                    let reqs: Vec<Request> = (0..pipeline).map(|_| Request::Ping).collect();
                    let mut samples = Vec::with_capacity(rounds);
                    for _ in 0..rounds {
                        let s0 = std::time::Instant::now();
                        c.send_batch(&reqs).expect("send");
                        let resps = c.recv_batch(pipeline).expect("recv");
                        assert!(resps.iter().all(|r| matches!(r, Response::Pong)));
                        samples.push(s0.elapsed().as_secs_f64() / pipeline as f64);
                    }
                    samples
                }));
            }
            let mut all = Vec::new();
            for h in handles {
                all.extend(h.join().expect("saturation client thread"));
            }
            let wall = t0.elapsed().as_secs_f64();
            (all, wall, (clients * pipeline * rounds) as u64)
        };
        let record_sat = |suite: &mut Suite, name: &str, samples: &[f64], wall: f64, total: u64| {
            let per_req = wall / total as f64;
            suite.record(BenchResult {
                name: format!("{name}_ns"),
                median: per_req,
                mean: per_req,
                p10: percentile(samples, 0.1),
                p90: percentile(samples, 0.9),
                iters: total,
                samples: samples.len(),
            });
            suite.record(BenchResult {
                name: format!("{name}_p99_ns"),
                median: percentile(samples, 0.99),
                mean: percentile(samples, 0.99),
                p10: percentile(samples, 0.5),
                p90: percentile(samples, 0.99),
                iters: total,
                samples: samples.len(),
            });
        };

        let cfg = CoordinatorConfig { k: 64, workers: 4, ..Default::default() };
        let coord = Arc::new(Coordinator::new(cfg.clone()).unwrap());
        let es = EventServer::start(coord.clone(), "127.0.0.1:0").unwrap();
        let (samples, wall, total) = saturate(es.addr.to_string(), true);
        es.stop();
        Arc::try_unwrap(coord).ok().expect("event server released the coordinator").shutdown();
        record_sat(&mut suite, "transport.sat.framed", &samples, wall, total);

        let coord = Arc::new(Coordinator::new(cfg).unwrap());
        let js = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
        let (samples, wall, total) = saturate(js.addr.to_string(), false);
        js.stop();
        Arc::try_unwrap(coord).ok().expect("json server released the coordinator").shutdown();
        record_sat(&mut suite, "transport.sat.json", &samples, wall, total);

        if let Some(sp) = suite.speedup("transport.sat.json_ns", "transport.sat.framed_ns") {
            println!(
                "  -> framed event transport sustained speedup over JSON lines \
                 ({clients} clients x {pipeline} in flight): {sp:.2}x"
            );
        }
    }

    if let Some(path) = json {
        match suite.write_json(&path) {
            Ok(()) => println!("  -> wrote {} results to {path}", suite.results.len()),
            Err(e) => {
                eprintln!("cannot write bench summary '{path}': {e}");
                std::process::exit(1);
            }
        }
    }
}
