//! `cargo bench` — regenerates every paper table/figure at quick scale
//! (the experiment harness itself; pass FASTGM_BENCH_FULL=1 for
//! paper-scale) plus micro-benchmarks of the coordinator hot paths.
//!
//! Uses the in-crate mini-criterion (`util::bench`) — the criterion crate
//! is not in the offline set. Results: stdout + results/bench_*.jsonl.

use fastgm::coordinator::protocol::{Request, Response};
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::data::corpus::Corpus;
use fastgm::data::synthetic::{dense_vector, WeightDist};
use fastgm::exp::{self, ExpOptions};
use fastgm::lsh::{LshIndex, LshParams};
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::bench::{Bencher, Suite};
use fastgm::util::rng::SplitMix64;

fn main() {
    fastgm::util::logger::init();
    let full = std::env::var("FASTGM_BENCH_FULL").is_ok();
    let opts = ExpOptions { out_dir: "results".into(), full };

    println!("== paper tables & figures (quick={}) ==", !full);
    for name in exp::ALL {
        println!("\n--- {name} ---");
        if let Err(e) = exp::run(name, &opts) {
            eprintln!("experiment {name} failed: {e}");
            std::process::exit(1);
        }
    }

    println!("\n== coordinator hot-path micro-benchmarks ==");
    let b = Bencher::from_env();
    let mut suite = Suite::new().with_jsonl(&opts.jsonl_path("bench_micro"));

    // Core sketching kernel across representative shapes.
    let mut rng = SplitMix64::new(42);
    for (n, k) in [(100usize, 256usize), (1000, 256), (1000, 1024), (10_000, 1024)] {
        let v = dense_vector(&mut rng, n, WeightDist::Uniform01);
        let fg = FastGm::new(k, 1);
        suite.record(b.run(&format!("fastgm/n{n}/k{k}"), || fg.sketch(&v)));
    }

    // Corpus-shaped sketching (sparse text vectors).
    let corpus = Corpus::by_name("real-sim", 7).unwrap();
    let docs = corpus.vectors(64);
    let fg = FastGm::new(256, 1);
    let mut i = 0;
    suite.record(b.run("fastgm/real-sim/k256", || {
        i = (i + 1) % docs.len();
        fg.sketch(&docs[i])
    }));

    // LSH query against a 2k-document index.
    let sketches: Vec<_> = corpus.vectors(2000).iter().map(|d| fg.sketch(d)).collect();
    let mut index = LshIndex::new(LshParams::for_threshold(256, 0.5));
    for (i, sk) in sketches.iter().enumerate() {
        index.insert(i as u64, sk.clone());
    }
    let mut q = 0;
    suite.record(b.run("lsh/query@2000docs", || {
        q = (q + 7) % sketches.len();
        index.query(&sketches[q], 10).unwrap()
    }));

    // In-process coordinator round-trip (worker pool + registry).
    let coord = Coordinator::new(CoordinatorConfig {
        k: 256,
        workers: 4,
        ..Default::default()
    })
    .unwrap();
    let v = SparseVector::new((0..100u64).collect(), vec![1.0; 100]);
    let mut n = 0u64;
    suite.record(b.run("coordinator/sketch-roundtrip", || {
        n += 1;
        let r = coord.call(Request::Sketch { name: format!("b{}", n % 64), vector: v.clone(), algo: None });
        assert!(matches!(r, Response::Sketch { .. }));
    }));
    suite.record(b.run("coordinator/ping-roundtrip", || coord.call(Request::Ping)));
    coord.shutdown();

    // Merge throughput (distributed-site central role).
    let site_sketches: Vec<_> = (0..32)
        .map(|i| {
            let v = SparseVector::new(
                (i * 50..i * 50 + 100u64).collect(),
                vec![1.0; 100],
            );
            fg.sketch(&v)
        })
        .collect();
    suite.record(b.run("merge/32sites/k256", || {
        fastgm::coordinator::merger::merge_tree(&site_sketches, 4).unwrap()
    }));

    println!("\nbench complete; JSONL in results/");
}
