//! The paper's Fig. 9/10 scenario: a braided-chain wireless sensor network
//! where every node sketches the traffic passing through it, and the
//! sketches answer set-algebra questions (per-source mass, losses, overlap)
//! that raw counters cannot (double counting).
//!
//! ```bash
//! cargo run --release --example sensor_network [DEPTH] [PACKETS]
//! ```

use fastgm::simnet::{NodeSketcher, SimNet, SimParams};
use fastgm::util::stats::fmt_duration;

fn main() {
    let depth = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let packets = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(10_000);
    let params = SimParams { depth, packets_per_source: packets, ..SimParams::default() };
    println!(
        "braided chain: d={depth}, n={packets}/source, p1={}, p2={}, k={} (Beta(5,5) sizes)",
        params.p1, params.p2, params.k
    );

    let net = SimNet::run(params, NodeSketcher::StreamFastGm);
    println!("per-node sketching total: {}\n", fmt_duration(net.sketch_seconds));

    let a = net.fig10a();
    let b = net.fig10b();
    let c = net.fig10c();
    let d = net.fig10d();
    println!(
        "{:>5} | {:>9} {:>9} | {:>7} {:>7} | {:>9} {:>9} | {:>7} {:>7}",
        "layer", "A-mass", "est", "mean", "est", "lost-A", "est", "J_W", "est"
    );
    for l in 0..params.depth {
        println!(
            "{l:>5} | {:>9.1} {:>9.1} | {:>7.3} {:>7.3} | {:>9.1} {:>9.1} | {:>7.3} {:>7.3}",
            a[l].0, a[l].1, b[l].0, b[l].1, c[l].0, c[l].1, d[l].0, d[l].1
        );
    }

    // Efficiency against the Lemiesz baseline on the same network.
    let lem = SimNet::run(params, NodeSketcher::Lemiesz);
    println!(
        "\nsketching cost: stream-fastgm {} vs lemiesz {} ({:.1}x faster)",
        fmt_duration(net.sketch_seconds),
        fmt_duration(lem.sketch_seconds),
        lem.sketch_seconds / net.sketch_seconds
    );
}
