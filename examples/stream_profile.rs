//! Profiling harness for the Stream-FastGM hot path (used for perf
//! iteration on the release-count hot loop):
//!
//! ```bash
//! cargo build --release --example stream_profile
//! perf record -F 999 ./target/release/examples/stream_profile
//! perf report --stdio | head -20
//! ```
//!
//! Prints the release count per iteration — the quantity the paper's
//! complexity analysis bounds (Algorithm 2 pays Θ(k ln k · ln n) releases
//! on randomly-ordered streams because y* shrinks gradually).

use fastgm::data::stream::generate;
use fastgm::data::synthetic::WeightDist;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::util::rng::SplitMix64;

fn main() {
    let mut rng = SplitMix64::new(42);
    let stream = generate(&mut rng, 1000, 1.0, WeightDist::Uniform01, 0);
    let mut acc = 0.0f64;
    let mut total_released = 0u64;
    let iters = 300;
    for it in 0..iters {
        let mut s = StreamFastGm::new(1024, it);
        for &(id, w) in &stream.events {
            s.push(id, w);
        }
        total_released += s.released;
        acc += s.sketch().y[0];
    }
    println!("checksum {acc:.6}; releases/iter = {}", total_released / iters);
}
