//! SIMILARITY SERVING, END TO END: the keyed sketch store over the wire.
//!
//!   1. start the coordinator + TCP server, `upsert` a document corpus
//!      (each vector sketched on the worker pool, LSH-indexed on arrival),
//!   2. answer `topk` near-duplicate queries (band probe + `estimate_jp`
//!      re-rank) and record the results,
//!   3. `snapshot` the store, **stop the server completely**, start a
//!      fresh one, `restore` — and verify the restored store answers the
//!      exact same queries with the exact same rankings (warm restart
//!      without recomputing a single sketch),
//!   4. report throughput, self-recall, and the sub-linear candidate rate
//!      from the server's own metrics.
//!
//! Runs offline in seconds; CI uses it as the serving-path smoke test.
//!
//! ```bash
//! cargo run --release --example similarity_serve
//! ```

use fastgm::coordinator::client::Client;
use fastgm::coordinator::protocol::{Request, Response};
use fastgm::coordinator::server::Server;
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::data::corpus::Corpus;
use fastgm::sketch::SparseVector;
use fastgm::util::rng::SplitMix64;
use std::sync::Arc;
use std::time::Instant;

const N_DOCS: usize = 400;
const K: usize = 128;
const SEED: u64 = 42;
const QUERIES: usize = 25;
const LIMIT: usize = 5;

fn config() -> CoordinatorConfig {
    CoordinatorConfig { k: K, seed: SEED, workers: 4, ..Default::default() }
}

/// Keep ~`keep` of the doc's mass, replace the rest with fresh ids.
fn perturb(rng: &mut SplitMix64, v: &SparseVector, keep: f64) -> SparseVector {
    let mut out = SparseVector::default();
    for (id, w) in v.positive() {
        if rng.next_f64() < keep {
            out.push(id, w);
        } else {
            out.push(rng.next_u64() | (1 << 63), w);
        }
    }
    out
}

fn counter(snapshot: &fastgm::util::json::Value, name: &str) -> f64 {
    snapshot
        .get("counters")
        .and_then(|c| c.get(name))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0)
}

fn main() -> anyhow::Result<()> {
    fastgm::util::logger::init();

    // ---- Phase 1: serve + ingest via `upsert`. --------------------------
    let coordinator = Arc::new(Coordinator::new(config())?);
    let server = Server::start(coordinator.clone(), "127.0.0.1:0")?;
    let corpus = Corpus::by_name("real-sim", 7).expect("real-sim corpus analog");
    let docs: Vec<SparseVector> = corpus.vectors(N_DOCS);
    let mut client = Client::connect(&server.addr.to_string())?;
    let t0 = Instant::now();
    for (base, chunk) in docs.chunks(64).enumerate().map(|(i, c)| (i * 64, c)) {
        let reqs: Vec<Request> = chunk
            .iter()
            .enumerate()
            .map(|(j, d)| Request::Upsert {
                key: format!("doc{}", base + j),
                vector: d.clone(),
                version: None,
            })
            .collect();
        for r in client.call_pipelined(&reqs)? {
            anyhow::ensure!(matches!(r, Response::Ack { .. }), "upsert failed: {r:?}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "upserted {N_DOCS} docs in {dt:.2}s ({:.0} docs/s over TCP, sketch+index, k={K})",
        N_DOCS as f64 / dt
    );
    let stats = client.store_stats()?;
    println!("store stats: {stats}");
    anyhow::ensure!(
        stats.get("size").and_then(|v| v.as_f64()) == Some(N_DOCS as f64),
        "store size drifted: {stats}"
    );

    // ---- Phase 2: top-k queries against the live store. -----------------
    let mut rng = SplitMix64::new(2024);
    let targets: Vec<usize> = (0..QUERIES).map(|_| rng.next_range(0, N_DOCS - 1)).collect();
    let query_vecs: Vec<SparseVector> =
        targets.iter().map(|&t| perturb(&mut rng, &docs[t], 0.9)).collect();
    let t0 = Instant::now();
    let mut live_hits = Vec::with_capacity(QUERIES);
    for q in &query_vecs {
        live_hits.push(client.topk(q.clone(), LIMIT)?);
    }
    let qdt = t0.elapsed().as_secs_f64();
    let self_recall = targets
        .iter()
        .zip(&live_hits)
        .filter(|(t, hits)| hits.first().map(|h| h.0 == format!("doc{t}")) == Some(true))
        .count();
    println!(
        "{QUERIES} top-{LIMIT} queries in {:.1} ms ({:.2} ms each), self-recall {}/{QUERIES}",
        qdt * 1e3,
        qdt * 1e3 / QUERIES as f64,
        self_recall
    );

    // ---- Phase 3: snapshot → full server restart → restore. -------------
    let snap_path =
        std::env::temp_dir().join(format!("fastgm-similarity-{}.fgms", std::process::id()));
    let snap_str = snap_path.to_string_lossy().to_string();
    println!("{}", client.snapshot(&snap_str)?);
    drop(client);
    server.stop();
    // stop() joined every connection, so this Arc is the last one standing.
    match Arc::try_unwrap(coordinator) {
        Ok(c) => c.shutdown(),
        Err(_) => anyhow::bail!("server.stop() left a coordinator reference alive"),
    }

    let coordinator = Arc::new(Coordinator::new(config())?);
    let server = Server::start(coordinator.clone(), "127.0.0.1:0")?;
    let mut client = Client::connect(&server.addr.to_string())?;
    println!("{}", client.restore(&snap_str)?);
    let mut restored_hits = Vec::with_capacity(QUERIES);
    for q in &query_vecs {
        restored_hits.push(client.topk(q.clone(), LIMIT)?);
    }
    anyhow::ensure!(
        live_hits == restored_hits,
        "restored store ranked neighbors differently than the live store"
    );
    println!("restored store reproduces all {QUERIES} rankings exactly ✓");

    // ---- Phase 4: candidate rate + mutation sanity. ---------------------
    let Response::MetricsDump { snapshot } = client.call(&Request::Metrics)? else {
        anyhow::bail!("bad metrics response")
    };
    let probes = counter(&snapshot, "ops.topk").max(1.0);
    let avg_candidates = counter(&snapshot, "topk.candidates") / probes;
    println!(
        "avg LSH candidates per query: {avg_candidates:.1} of {N_DOCS} stored ({:.1}%)",
        100.0 * avg_candidates / N_DOCS as f64
    );
    println!("{}", client.delete("doc0")?);
    let stats = client.store_stats()?;
    anyhow::ensure!(
        stats.get("size").and_then(|v| v.as_f64()) == Some((N_DOCS - 1) as f64),
        "delete did not shrink the store: {stats}"
    );

    server.stop();
    std::fs::remove_file(&snap_path).ok();
    anyhow::ensure!(self_recall as f64 / QUERIES as f64 > 0.9, "self-recall too low");
    anyhow::ensure!(avg_candidates < N_DOCS as f64 / 2.0, "probing is not sub-linear");
    println!("\nsimilarity_serve OK");
    Ok(())
}
