//! END-TO-END DRIVER: the full three-layer system on a real small workload.
//!
//! Starts the coordinator + TCP server in-process (accelerator enabled when
//! `artifacts/` is built), then drives it with concurrent clients over the
//! wire:
//!
//!   1. ingest a document corpus (sparse → CPU FastGM workers),
//!   2. build the LSH index,
//!   3. mixed query load from 4 client threads: LSH similarity queries,
//!      pairwise J_P estimates, stream pushes + cardinality reads, and
//!      dense sketches (batched onto the AOT Pallas artifact when present),
//!   4. report throughput, latency percentiles, estimate accuracy, and the
//!      server's own metrics.
//!
//! ```bash
//! cargo run --release --example serve_e2e
//! ```

use fastgm::coordinator::client::Client;
use fastgm::coordinator::protocol::{Request, Response};
use fastgm::coordinator::server::Server;
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::data::corpus::Corpus;
use fastgm::estimate::jaccard::probability_jaccard;
use fastgm::sketch::SparseVector;
use fastgm::util::rng::SplitMix64;
use fastgm::util::stats::percentile;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N_DOCS: usize = 2000;
const K: usize = 256;

fn main() -> anyhow::Result<()> {
    fastgm::util::logger::init();
    let artifacts = if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts".to_string())
    } else {
        eprintln!("note: artifacts/ not built — dense path uses CPU fallback");
        None
    };
    let coordinator = Arc::new(Coordinator::new(CoordinatorConfig {
        k: K,
        workers: 4,
        artifacts_dir: artifacts,
        batch_max: 8,
        batch_deadline: Duration::from_millis(2),
        ..Default::default()
    })?);
    println!("accelerator enabled: {}", coordinator.accel_enabled());
    let server = Server::start(coordinator, "127.0.0.1:0")?;
    let addr = server.addr.to_string();

    // ---- Phase 1: ingest corpus over the wire (pipelined). -------------
    let corpus = Corpus::by_name("rcv1", 7).unwrap();
    let docs: Vec<SparseVector> = corpus.vectors(N_DOCS);
    let t0 = Instant::now();
    // Indexed ingestion, pipelined in 64-doc batches.
    let mut client = Client::connect(&addr)?;
    let mut ingested = 0;
    let mut base = 0usize;
    while base < docs.len() {
        let end = (base + 64).min(docs.len());
        let reqs: Vec<Request> = (base..end)
            .map(|i| Request::Sketch { name: format!("doc{i}"), vector: docs[i].clone(), algo: None })
            .collect();
        for r in client.call_pipelined(&reqs)? {
            assert!(matches!(r, Response::Sketch { .. }), "ingest failed: {r:?}");
            ingested += 1;
        }
        base = end;
    }
    let ingest_dt = t0.elapsed().as_secs_f64();
    println!(
        "ingested {ingested} docs in {:.2}s  ({:.0} docs/s over TCP, FastGM k={K})",
        ingest_dt,
        ingested as f64 / ingest_dt
    );

    // ---- Phase 2: LSH index. -------------------------------------------
    let t0 = Instant::now();
    let reqs: Vec<Request> =
        (0..docs.len()).map(|i| Request::LshInsert { name: format!("doc{i}") }).collect();
    for chunk in reqs.chunks(128) {
        for r in client.call_pipelined(chunk)? {
            assert!(matches!(r, Response::Ack { .. }));
        }
    }
    println!("indexed {} docs in {:.2}s", docs.len(), t0.elapsed().as_secs_f64());

    // ---- Phase 3: mixed query load from 4 concurrent clients. ----------
    let queries_per_client = 150;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..4u64)
        .map(|tid| {
            let addr = addr.clone();
            let docs = docs.clone();
            std::thread::spawn(move || -> anyhow::Result<(Vec<f64>, usize, f64)> {
                let mut client = Client::connect(&addr)?;
                let mut rng = SplitMix64::new(500 + tid);
                let mut latencies = Vec::new();
                let mut lsh_hits = 0;
                let mut jp_sq_err = 0.0;
                for q in 0..queries_per_client {
                    let t0 = Instant::now();
                    match q % 4 {
                        0 => {
                            // LSH near-duplicate query for a known doc.
                            let target = rng.next_range(0, docs.len() - 1);
                            let Response::TopK { hits } = client.call(&Request::LshQuery {
                                vector: docs[target].clone(),
                                limit: 5,
                            })?
                            else {
                                anyhow::bail!("bad lsh response")
                            };
                            if hits.first().map(|h| h.0 == format!("doc{target}")) == Some(true) {
                                lsh_hits += 1;
                            }
                        }
                        1 => {
                            // Pairwise J_P vs exact.
                            let a = rng.next_range(0, docs.len() - 1);
                            let b = rng.next_range(0, docs.len() - 1);
                            let Response::Estimate { value } = client.call(&Request::Jaccard {
                                a: format!("doc{a}"),
                                b: format!("doc{b}"),
                            })?
                            else {
                                anyhow::bail!("bad jaccard response")
                            };
                            let truth = probability_jaccard(&docs[a], &docs[b]);
                            jp_sq_err += (value - truth) * (value - truth);
                        }
                        2 => {
                            // Stream push + cardinality.
                            let items: Vec<(u64, f64)> =
                                (0..32).map(|i| (rng.next_range(0, 5000) as u64 * 7 + i, 1.0)).collect();
                            client.call(&Request::Push { stream: format!("s{tid}"), items })?;
                            client.call(&Request::Cardinality { stream: format!("s{tid}") })?;
                        }
                        _ => {
                            // Dense sketch → accelerator batcher.
                            let dense: Vec<f64> =
                                (0..512).map(|_| if rng.next_f64() < 0.5 { 0.0 } else { rng.next_f64() }).collect();
                            let Response::Sketch { .. } = client.call(&Request::SketchDense {
                                name: format!("dense{tid}_{q}"),
                                weights: dense,
                            })?
                            else {
                                anyhow::bail!("bad dense response")
                            };
                        }
                    }
                    latencies.push(t0.elapsed().as_secs_f64());
                }
                Ok((latencies, lsh_hits, jp_sq_err))
            })
        })
        .collect();

    let mut latencies = Vec::new();
    let mut lsh_hits = 0;
    let mut jp_sq_err = 0.0;
    for h in handles {
        let (l, hits, err) = h.join().expect("client thread")?;
        latencies.extend(l);
        lsh_hits += hits;
        jp_sq_err += err;
    }
    let wall = t0.elapsed().as_secs_f64();
    let total_q = latencies.len();
    println!("\n== mixed query load ==");
    println!("throughput: {:.0} req/s ({total_q} requests, 4 clients, {wall:.2}s wall)",
        total_q as f64 / wall);
    println!(
        "latency: p50 {:.2} ms, p90 {:.2} ms, p99 {:.2} ms",
        percentile(&latencies, 0.5) * 1e3,
        percentile(&latencies, 0.9) * 1e3,
        percentile(&latencies, 0.99) * 1e3
    );
    let lsh_total = 4 * (0..queries_per_client).filter(|q| q % 4 == 0).count();
    println!("LSH self-recall: {:.1}%", 100.0 * lsh_hits as f64 / lsh_total as f64);
    let jp_total = 4 * (0..queries_per_client).filter(|q| q % 4 == 1).count();
    let jp_rmse = (jp_sq_err / jp_total as f64).sqrt();
    println!("J_P RMSE vs exact: {jp_rmse:.4} (theory ≈ {:.4} at J≈0.05)",
        (0.05f64 * 0.95 / K as f64).sqrt());

    // ---- Phase 4: server metrics. ---------------------------------------
    let Response::MetricsDump { snapshot } = client.call(&Request::Metrics)? else {
        anyhow::bail!("bad metrics response")
    };
    println!("\nserver metrics: {snapshot}");

    server.stop();
    assert!(lsh_hits as f64 / lsh_total as f64 > 0.9, "LSH recall too low");
    assert!(jp_rmse < 0.1, "J_P estimates off");
    println!("\nserve_e2e OK");
    Ok(())
}
