//! REPLICATED CLUSTER SERVING, END TO END: three real nodes, R=2, one
//! client — the "one dead node is invisible" story.
//!
//!   1. spawn a 3-node local cluster (real TCP on loopback), connect the
//!      `ClusterClient` at replication R=2, write quorum W=1: every key
//!      and stream partition lives on its HRW top-2 owners,
//!   2. ingest a corpus (each upsert acks from both replicas) and a
//!      weighted stream; record the exact `topk` rankings and the merged
//!      cardinality sketch of the healthy cluster,
//!   3. **kill one node** and show replication at work: `topk` rankings
//!      and the merged stream sketch are IDENTICAL to the healthy
//!      cluster's — not degraded — while quorum writes keep landing on
//!      the surviving replicas (and a W=2 quorum correctly reports
//!      `QuorumLost`, naming the dead node),
//!   4. restart the node **cold** (empty store, empty streams) and run
//!      `cluster repair`: the anti-entropy walk diffs `(key, version)`
//!      pages across the replica sets, streams codec blobs onto the cold
//!      node (last-writer-wins), and §2.3-merges the stream states,
//!   5. verify convergence: every key's version and registers are
//!      bit-identical across its replica set, the downtime writes
//!      included, and the cluster again answers with the exact healthy
//!      rankings at full quorum.
//!
//! Runs offline in seconds; CI uses it as the replication smoke test.
//!
//! ```bash
//! cargo run --release --example replicated_serve
//! ```

use fastgm::coordinator::client::Client;
use fastgm::coordinator::cluster::{ClusterClient, ClusterError, LocalCluster, ReplicaConfig};
use fastgm::coordinator::protocol::SketchSource;
use fastgm::coordinator::service::CoordinatorConfig;
use fastgm::data::corpus::Corpus;
use fastgm::sketch::SparseVector;
use fastgm::util::rng::SplitMix64;
use std::time::Instant;

const NODES: usize = 3;
const N_DOCS: usize = 180;
const K: usize = 128;
const SEED: u64 = 42;
const QUERIES: usize = 12;
const LIMIT: usize = 5;
const STREAM_N: u64 = 1500;

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        k: K,
        seed: SEED,
        workers: 2,
        node_id: "site".into(),
        ..Default::default()
    }
}

/// Keep ~`keep` of the doc's mass, replace the rest with fresh ids.
fn perturb(rng: &mut SplitMix64, v: &SparseVector, keep: f64) -> SparseVector {
    let mut out = SparseVector::default();
    for (id, w) in v.positive() {
        if rng.next_f64() < keep {
            out.push(id, w);
        } else {
            out.push(rng.next_u64() | (1 << 63), w);
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    fastgm::util::logger::init();

    // ---- Phase 1: spawn, connect at R=2 W=1. ----------------------------
    let mut cluster = LocalCluster::start(NODES, &config())?;
    let mut cc = ClusterClient::connect_with(
        &cluster.addrs(),
        ReplicaConfig { replication: 2, write_quorum: 1, ..Default::default() },
    )?;
    println!(
        "cluster up: {} nodes, replication R={} write-quorum W={}",
        cc.nodes(),
        cc.replication().replication,
        cc.replication().write_quorum,
    );
    for i in 0..cc.nodes() {
        let h = cc.hello(i);
        println!("  {} @ {} (protocol v{}, epoch {})", h.node, cc.addr(i), h.protocol, h.epoch);
    }

    // ---- Phase 2: replicated ingest + healthy baselines. ----------------
    let corpus = Corpus::by_name("real-sim", 7).expect("real-sim corpus analog");
    let docs: Vec<SparseVector> = corpus.vectors(N_DOCS);
    let t0 = Instant::now();
    for (i, d) in docs.iter().enumerate() {
        let info = cc.upsert(&format!("doc{i:03}"), d.clone())?;
        anyhow::ensure!(info.contains("(2/2 replicas)"), "healthy ack: {info}");
    }
    let dt = t0.elapsed().as_secs_f64();
    let sizes = cc.store_sizes();
    let total: f64 = sizes.iter().filter_map(|(_, s)| *s).sum();
    println!(
        "upserted {N_DOCS} docs x2 replicas in {dt:.2}s ({:.0} docs/s), occupancy: {sizes:?}",
        N_DOCS as f64 / dt,
    );
    anyhow::ensure!(
        total == (2 * N_DOCS) as f64,
        "R=2 must store every key exactly twice: {total} vs {}",
        2 * N_DOCS
    );
    let items: Vec<(u64, f64)> = (0..STREAM_N).map(|i| (i * 977 + 13, 1.0)).collect();
    cc.push("pkts", &items)?;

    let mut rng = SplitMix64::new(2024);
    let query_vecs: Vec<SparseVector> = (0..QUERIES)
        .map(|_| {
            let t = rng.next_range(0, N_DOCS - 1);
            perturb(&mut rng, &docs[t], 0.9)
        })
        .collect();
    let mut healthy = Vec::with_capacity(QUERIES);
    for q in &query_vecs {
        healthy.push(cc.topk(q, LIMIT)?.0);
    }
    let healthy_sketch = cc.merged_stream_sketch("pkts")?;
    let healthy_card = cc.cardinality("pkts")?;
    println!(
        "healthy baselines: {QUERIES} top-{LIMIT} rankings, cardinality {healthy_card:.1} \
         (truth {STREAM_N})"
    );

    // ---- Phase 3: kill one node — reads stay IDENTICAL. -----------------
    const VICTIM: usize = 1;
    let victim_id = cc.node_id(VICTIM).to_string();
    println!("killing {victim_id} ...");
    cluster.kill(VICTIM);
    for (qi, q) in query_vecs.iter().enumerate() {
        let (hits, stats) = cc.topk(q, LIMIT)?;
        anyhow::ensure!(stats.live == NODES - 1, "{stats:?}");
        anyhow::ensure!(hits == healthy[qi], "query {qi}: rankings drifted with one node down");
    }
    anyhow::ensure!(
        cc.merged_stream_sketch("pkts")? == healthy_sketch,
        "merged stream sketch must be bit-identical with one replica down"
    );
    println!("one node down: all {QUERIES} rankings + cardinality sketch IDENTICAL ✓");

    // Writes: W=1 keeps the cluster writable through the outage ...
    let downtime_key = (0..)
        .map(|i| format!("downtime{i}"))
        .find(|k| cc.owners(k).contains(&VICTIM))
        .expect("some key owned by the victim");
    let filler = SparseVector::new(
        (0..12u64).map(|j| 900_000_000_000 + j).collect(),
        (0..12).map(|_| 1.0).collect(),
    );
    let info = cc.upsert(&downtime_key, filler.clone())?;
    anyhow::ensure!(info.contains("(1/2 replicas)"), "degraded ack: {info}");
    println!("downtime write '{downtime_key}' → {info} ✓");
    // ... while a W=2 quorum correctly refuses, naming the dead node.
    cc.set_write_quorum(2)?;
    match cc.upsert(&downtime_key, filler) {
        Err(ClusterError::QuorumLost { acked, want, down, .. }) => {
            anyhow::ensure!(down == vec![victim_id.clone()], "down list: {down:?}");
            println!("W=2 write → typed QuorumLost ({acked}/{want}, down: {down:?}) ✓");
        }
        other => anyhow::bail!("expected QuorumLost at W=2, got {other:?}"),
    }
    cc.set_write_quorum(1)?;

    // ---- Phase 4: cold restart + anti-entropy repair. -------------------
    cluster.restart(VICTIM)?;
    cc.reconnect(VICTIM, cluster.addr(VICTIM))?;
    let t0 = Instant::now();
    let report = cc.repair(&["pkts".to_string()])?;
    println!(
        "repair in {:.0} ms: {} keys scanned, {} replica installs, {} skipped, {} stream merges",
        t0.elapsed().as_secs_f64() * 1e3,
        report.keys_scanned,
        report.keys_healed,
        report.keys_skipped,
        report.stream_merges,
    );
    anyhow::ensure!(report.keys_healed > 0, "a cold node must need healing");

    // ---- Phase 5: convergence, bit for bit. -----------------------------
    let mut direct: Vec<Client> = (0..NODES)
        .map(|i| Client::connect(cluster.addr(i)))
        .collect::<anyhow::Result<_>>()?;
    let mut checked = 0usize;
    for i in 0..NODES {
        for (key, version) in cc.node_keys(i)? {
            let owners = cc.owners(&key);
            let copies: Vec<_> = owners
                .iter()
                .map(|&o| direct[o].sketch_fetch_versioned(&key, SketchSource::Store))
                .collect::<anyhow::Result<_>>()?;
            for (v, sk) in &copies[1..] {
                anyhow::ensure!(
                    (*v, sk) == (copies[0].0, &copies[0].1),
                    "'{key}' (v{version}) diverged across its replica set"
                );
            }
            checked += 1;
        }
    }
    println!("verified {checked} (key, replica-set) version+register convergences ✓");
    for d in direct.iter_mut() {
        anyhow::ensure!(
            d.sketch_fetch("pkts", SketchSource::Stream)? == healthy_sketch,
            "stream state did not converge to the §2.3 union"
        );
    }
    // The downtime write reached the healed node too.
    let (v_down, _) = direct[VICTIM].sketch_fetch_versioned(&downtime_key, SketchSource::Store)?;
    println!("downtime write '{downtime_key}' healed onto {victim_id} @v{v_down} ✓");

    // Healthy answers, full quorum, all over again.
    for (qi, q) in query_vecs.iter().enumerate() {
        let (hits, stats) = cc.topk(q, LIMIT)?;
        anyhow::ensure!(stats.live == NODES && hits == healthy[qi], "query {qi} after repair");
    }
    cc.set_write_quorum(2)?;
    let info = cc.upsert("post-repair", docs[0].clone())?;
    anyhow::ensure!(info.contains("(2/2 replicas)"), "{info}");
    println!("post-repair: rankings identical, W=2 writes back ({info}) ✓");

    cluster.stop();
    println!("\nreplicated_serve OK");
    Ok(())
}
