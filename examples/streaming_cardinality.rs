//! Distributed weighted-cardinality estimation (Task 2, §2.3).
//!
//! Eight "sites" each observe an overlapping slice of a weighted object
//! stream; each builds a Stream-FastGM sketch locally; a central site
//! merges the eight k-register sketches (the only communication!) and
//! estimates the global deduplicated weighted cardinality.
//!
//! ```bash
//! cargo run --release --example streaming_cardinality
//! ```

use fastgm::data::stream::generate;
use fastgm::data::synthetic::WeightDist;
use fastgm::estimate::cardinality::{cardinality_rel_std, estimate_cardinality};
use fastgm::coordinator::merger::merge_tree;
use fastgm::sketch::lemiesz::LemieszSketch;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::util::rng::SplitMix64;
use fastgm::util::stats::fmt_duration;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let k = 512;
    let sites = 8;
    let objects_per_site = 50_000;
    let mut rng = SplitMix64::new(3);

    // Sites share a global object universe; slices overlap 50%.
    let universe = generate(&mut rng, objects_per_site * sites / 2, 0.5, WeightDist::Uniform01, 0);
    let all: Vec<(u64, f64)> = universe.weights.iter().map(|(&i, &w)| (i, w)).collect();

    println!("{sites} sites × ~{objects_per_site} events, k={k}");
    let mut site_sketches = Vec::new();
    let mut fast_total = 0.0;
    let mut lem_total = 0.0;
    let mut seen = std::collections::HashSet::new();
    for s in 0..sites {
        // Each site sees a random overlapping slice, with duplicates.
        let mut events = Vec::with_capacity(objects_per_site);
        let mut srng = SplitMix64::new(1000 + s as u64);
        for _ in 0..objects_per_site {
            let &(id, w) = &all[srng.next_range(0, all.len() - 1)];
            events.push((id, w));
            seen.insert(id);
        }
        // Stream-FastGM (the paper's fast path).
        let t0 = Instant::now();
        let mut sk = StreamFastGm::new(k, 7);
        for &(id, w) in &events {
            sk.push(id, w);
        }
        fast_total += t0.elapsed().as_secs_f64();
        site_sketches.push(sk.sketch());
        // Lemiesz baseline for the same events (timing comparison only).
        let t0 = Instant::now();
        let mut lem = LemieszSketch::new(k, 7);
        for &(id, w) in &events {
            lem.push(id, w);
        }
        lem_total += t0.elapsed().as_secs_f64();
    }

    // Central site: merge eight sketches — k registers each, nothing else.
    let merged = merge_tree(&site_sketches, 4)?;
    let est = estimate_cardinality(&merged);
    let truth: f64 = all
        .iter()
        .filter(|(id, _)| seen.contains(id))
        .map(|(_, w)| w)
        .sum();
    let rel_err = (est - truth).abs() / truth;
    println!("merged estimate = {est:.1}   truth = {truth:.1}   rel err = {:.2}%", rel_err * 100.0);
    println!("theory rel-std  = {:.2}%  (√(2/k))", cardinality_rel_std(k) * 100.0);
    println!(
        "site sketching: stream-fastgm {} vs lemiesz {}  ({:.1}x faster)",
        fmt_duration(fast_total),
        fmt_duration(lem_total),
        lem_total / fast_total
    );
    println!(
        "communication: {} sites × {} registers instead of {} raw events",
        sites,
        k,
        sites * objects_per_site
    );
    assert!(rel_err < 4.0 * cardinality_rel_std(k), "estimate outside 4σ");
    Ok(())
}
