//! Similarity search over a document corpus: FastGM sketches + banded LSH.
//!
//! Builds the `real-sim` corpus analog, indexes N documents, then answers
//! near-duplicate queries, reporting recall@10 against brute force and the
//! sub-linear candidate rate.
//!
//! ```bash
//! cargo run --release --example similarity_search [N_DOCS]
//! ```

use fastgm::data::corpus::Corpus;
use fastgm::estimate::jaccard::estimate_jp;
use fastgm::lsh::{LshIndex, LshParams};
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::{Sketcher, SparseVector};
use fastgm::util::rng::SplitMix64;
use std::time::Instant;

fn perturb(rng: &mut SplitMix64, v: &SparseVector, keep: f64) -> SparseVector {
    let mut out = SparseVector::default();
    for (id, w) in v.positive() {
        if rng.next_f64() < keep {
            out.push(id, w);
        } else {
            out.push(rng.next_u64() | (1 << 63), w);
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    let n_docs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3000);
    let k = 256;
    let corpus = Corpus::by_name("real-sim", 7).unwrap();
    let sketcher = FastGm::new(k, 11);
    let mut rng = SplitMix64::new(99);

    println!("indexing {n_docs} documents (k={k}) ...");
    let t0 = Instant::now();
    let docs: Vec<SparseVector> = corpus.vectors(n_docs);
    let sketches: Vec<_> = docs.iter().map(|d| sketcher.sketch(d)).collect();
    let sketch_time = t0.elapsed();
    let t0 = Instant::now();
    let mut index = LshIndex::new(LshParams::for_threshold(k, 0.5));
    for (i, sk) in sketches.iter().enumerate() {
        index.insert(i as u64, sk.clone());
    }
    println!(
        "  sketching: {:?} ({:.1} µs/doc), indexing: {:?}",
        sketch_time,
        sketch_time.as_secs_f64() * 1e6 / n_docs as f64,
        t0.elapsed()
    );

    // Queries: perturbed copies of random documents (ground truth = source).
    let n_queries = 200;
    let mut found = 0;
    let mut candidates_total = 0usize;
    let mut query_time = 0.0;
    for q in 0..n_queries {
        let target = rng.next_range(0, n_docs - 1);
        let query_vec = perturb(&mut rng, &docs[target], 0.9);
        let query_sk = sketcher.sketch(&query_vec);
        let t0 = Instant::now();
        let hits = index.query(&query_sk, 10)?;
        query_time += t0.elapsed().as_secs_f64();
        candidates_total += index.candidates(&query_sk).len();
        if hits.iter().any(|&(id, _)| id == target as u64) {
            found += 1;
        } else if q < 3 {
            // Show the brute-force check for the first misses.
            let brute = estimate_jp(&query_sk, &sketches[target])?;
            println!("  miss: target {target} est-sim {brute:.3}");
        }
    }
    println!(
        "recall@10 = {:.1}%  ({found}/{n_queries} perturbed queries)",
        100.0 * found as f64 / n_queries as f64
    );
    println!(
        "mean candidates/query = {:.1} of {n_docs} docs ({:.2}%) — sub-linear probe",
        candidates_total as f64 / n_queries as f64,
        100.0 * candidates_total as f64 / (n_queries * n_docs) as f64
    );
    println!("mean query latency = {:.1} µs", query_time * 1e6 / n_queries as f64);
    assert!(found as f64 / n_queries as f64 > 0.8, "recall collapsed");
    Ok(())
}
