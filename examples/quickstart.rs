//! Quickstart: the 60-second tour of the FastGM library.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fastgm::estimate::cardinality::estimate_cardinality;
use fastgm::estimate::jaccard::{estimate_jp, probability_jaccard};
use fastgm::sketch::engine::{self, EngineParams};
use fastgm::sketch::fastgm::FastGm;
use fastgm::sketch::stream_fastgm::StreamFastGm;
use fastgm::sketch::{GumbelMaxSketch, SketchScratch, Sketcher, SparseVector};

fn main() -> anyhow::Result<()> {
    // Two weighted vectors (e.g. TF-IDF bags of words). Ids are arbitrary
    // u64 (hash your tokens); weights must be positive.
    let doc_a = SparseVector::new(vec![1, 2, 3, 4], vec![1.0, 0.5, 2.0, 1.0]);
    let doc_b = SparseVector::new(vec![1, 2, 3, 9], vec![1.0, 0.5, 2.0, 1.5]);

    // 1. Sketch with FastGM — O(k ln k + n⁺) instead of O(k·n⁺).
    let k = 1024;
    let sketcher = FastGm::new(k, /*seed=*/ 42);
    let sk_a = sketcher.sketch(&doc_a);
    let sk_b = sketcher.sketch(&doc_b);

    // 2. Probability Jaccard similarity from the ArgMax registers.
    let est = estimate_jp(&sk_a, &sk_b)?;
    let truth = probability_jaccard(&doc_a, &doc_b);
    println!("J_P estimate = {est:.4}   (exact = {truth:.4}, k = {k})");

    // 3. Weighted cardinality from the Max registers: ĉ = (k-1)/Σy.
    let card = estimate_cardinality(&sk_a);
    println!("weighted cardinality of A ≈ {card:.2}   (exact = {})", doc_a.total_weight());

    // 4. Streams: one-pass Stream-FastGM with duplicate-safe updates.
    let mut stream = StreamFastGm::new(k, 42);
    for (id, w) in doc_a.positive() {
        stream.push(id, w);
        stream.push(id, w); // duplicates are free
    }
    assert_eq!(stream.sketch(), sk_a, "stream == batch, bit for bit");
    println!("stream sketch identical to batch sketch ✓");

    // 5. Mergeability (§2.3): union semantics across distributed sites.
    let merged = GumbelMaxSketch::merge_all([&sk_a, &sk_b])?;
    println!(
        "merged (union) cardinality ≈ {:.2}",
        estimate_cardinality(&merged)
    );

    // 6. The engine registry: any algorithm by name, and the
    //    zero-allocation hot path — reuse one scratch + output across
    //    calls (bit-identical to fresh sketches, just without the churn).
    let engine = engine::build_named("fastgm", EngineParams::new(k, 42))?;
    let mut scratch = SketchScratch::new();
    let mut out = GumbelMaxSketch::empty(engine.family(), engine.seed(), engine.k());
    engine.sketch_into(&doc_a, &mut scratch, &mut out);
    assert_eq!(out, sk_a, "engine + reused scratch == fresh sketch");
    engine.sketch_into(&doc_b, &mut scratch, &mut out);
    assert_eq!(out, sk_b);
    println!("engine registry + scratch reuse ✓ (algos: fastgm, fastgm-c, sharded, stream, pminhash, lemiesz, icws, bagminhash, minhash)");
    Ok(())
}
