//! WEIGHTED SAMPLING AS A SERVICE, END TO END: the query engine over the
//! wire — every register of a Gumbel-Max sketch is an independent weighted
//! sample, so a server that keeps sketches can answer `sample` and
//! `partition` queries without ever touching the raw data again.
//!
//!   1. start the coordinator + TCP server and `upsert` a catalog of
//!      category vectors with hand-computable total weights,
//!   2. `sample` one category: same seed ⇒ the same draws (reproducible
//!      pipelines), and the empirical frequencies track w_i/Σw,
//!   3. `sample` a key UNION: merging sketches (§2.3) is bit-identical to
//!      sketching the concatenated catalog, so the union draws from the
//!      multi-key target equal the draws from a single pre-merged key,
//!   4. `partition`: the sum-of-weights estimate for each category and for
//!      the union lands within the documented √(2/k) error band,
//!   5. `push` a weighted event stream and sample/estimate it live,
//!   6. spawn a 3-node cluster at R=2, kill a node, and show `sample` and
//!      `partition` fail over to the surviving replicas with IDENTICAL
//!      answers — determinism makes the outage invisible.
//!
//! Runs offline in seconds; CI uses it as the sampling-path smoke test.
//!
//! ```bash
//! cargo run --release --example sampling_serve
//! ```

use fastgm::coordinator::client::Client;
use fastgm::coordinator::cluster::{ClusterClient, LocalCluster, ReplicaConfig};
use fastgm::coordinator::protocol::{QueryTarget, Request, Response};
use fastgm::coordinator::server::Server;
use fastgm::coordinator::service::{Coordinator, CoordinatorConfig};
use fastgm::estimate::sample;
use fastgm::sketch::SparseVector;
use std::collections::HashMap;
use std::sync::Arc;

const CATS: usize = 6;
const ITEMS: usize = 60;
const K: usize = 256;
const SEED: u64 = 42;
const DRAWS: usize = 2000;

fn config() -> CoordinatorConfig {
    CoordinatorConfig { k: K, seed: SEED, workers: 2, ..Default::default() }
}

/// Category `c`: disjoint ids `c*1000 + i` with deterministic weights, so
/// the true partition function of every target is computable by hand.
fn category(c: usize) -> SparseVector {
    let mut v = SparseVector::default();
    for i in 0..ITEMS {
        v.push((c * 1000 + i) as u64, 1.0 + ((i * 7 + c) % 5) as f64 * 0.5);
    }
    v
}

fn true_weight(v: &SparseVector) -> f64 {
    v.weights.iter().sum()
}

fn main() -> anyhow::Result<()> {
    fastgm::util::logger::init();

    // ---- Phase 1: serve + ingest the catalog. ---------------------------
    let coordinator = Arc::new(Coordinator::new(config())?);
    let server = Server::start(coordinator, "127.0.0.1:0")?;
    let mut client = Client::connect(&server.addr.to_string())?;
    let cats: Vec<SparseVector> = (0..CATS).map(category).collect();
    let mut union_vec = SparseVector::default();
    for (c, v) in cats.iter().enumerate() {
        client.upsert(&format!("cat{c}"), v.clone())?;
        for (id, w) in v.positive() {
            union_vec.push(id, w);
        }
    }
    // The pre-merged catalog, stored as one key — the §2.3 reference point.
    client.upsert("catalog", union_vec.clone())?;
    println!("ingested {CATS} categories + 1 pre-merged catalog key (k={K})");

    // ---- Phase 2: single-key sampling — reproducible and frequency-true.
    let draws = client.sample(QueryTarget::key("cat0"), DRAWS, 7)?;
    anyhow::ensure!(
        draws == client.sample(QueryTarget::key("cat0"), DRAWS, 7)?,
        "same seed must reproduce the same draws"
    );
    anyhow::ensure!(
        draws != client.sample(QueryTarget::key("cat0"), DRAWS, 8)?,
        "a different seed should reshuffle the draws"
    );
    let mut freq: HashMap<u64, usize> = HashMap::new();
    for &id in &draws {
        anyhow::ensure!(id < ITEMS as u64, "cat0 sample outside cat0's id range: {id}");
        *freq.entry(id).or_default() += 1;
    }
    let total0 = true_weight(&cats[0]);
    let (heavy_id, heavy_w) = cats[0]
        .positive()
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .expect("non-empty category");
    let heavy_freq = freq.get(&heavy_id).copied().unwrap_or(0) as f64 / DRAWS as f64;
    let heavy_share = heavy_w / total0;
    println!(
        "cat0: {DRAWS} draws over {} distinct items, heaviest id {heavy_id} drawn {:.1}% \
         (true share {:.1}%)",
        freq.len(),
        100.0 * heavy_freq,
        100.0 * heavy_share,
    );
    // k registers cap the resolution: allow generous register-noise slack
    // (share std ≈ sqrt(p(1-p)/k) ≈ 1% here; 0.05 is a ~5σ band).
    anyhow::ensure!(
        (heavy_freq - heavy_share).abs() < 0.05,
        "empirical frequency drifted from w_i/Σw: {heavy_freq} vs {heavy_share}"
    );

    // ---- Phase 3: union sampling == pre-merged key, bit for bit. --------
    let keys: Vec<String> = (0..CATS).map(|c| format!("cat{c}")).collect();
    let union_draws = client.sample(QueryTarget::Keys(keys.clone()), 64, 9)?;
    let merged_draws = client.sample(QueryTarget::key("catalog"), 64, 9)?;
    anyhow::ensure!(
        union_draws == merged_draws,
        "§2.3 merge must make the key union indistinguishable from the pre-merged catalog"
    );
    println!("union over {CATS} keys == pre-merged catalog key: 64/64 draws identical ✓");

    // ---- Phase 4: partition-function estimates. -------------------------
    let rel_std = sample::partition_rel_std(K);
    for (c, v) in cats.iter().enumerate() {
        let est = client.partition(QueryTarget::key(format!("cat{c}")))?;
        let truth = true_weight(v);
        let rel_err = (est - truth).abs() / truth;
        println!("  partition(cat{c}) ≈ {est:9.1}  (truth {truth:7.1}, rel err {rel_err:.3})");
        anyhow::ensure!(rel_err < 6.0 * rel_std, "partition estimate outside the 6σ band");
    }
    let union_est = client.partition(QueryTarget::Keys(keys.clone()))?;
    let union_truth = true_weight(&union_vec);
    println!(
        "  partition(union) ≈ {union_est:9.1}  (truth {union_truth:7.1}, documented rel std \
         √(2/k) = {rel_std:.3})"
    );
    anyhow::ensure!((union_est - union_truth).abs() / union_truth < 6.0 * rel_std);

    // ---- Phase 5: streams are targets too. ------------------------------
    let items: Vec<(u64, f64)> = (0..500u64).map(|i| (i, 1.0 + (i % 3) as f64)).collect();
    let stream_truth: f64 = items.iter().map(|&(_, w)| w).sum();
    let resp = client.call(&Request::Push { stream: "events".into(), items })?;
    anyhow::ensure!(matches!(resp, Response::Ack { .. }), "push failed: {resp:?}");
    let stream_draws = client.sample(QueryTarget::Stream("events".into()), 32, 11)?;
    anyhow::ensure!(stream_draws.iter().all(|&id| id < 500), "stream sample outside id range");
    let stream_est = client.partition(QueryTarget::Stream("events".into()))?;
    println!(
        "stream 'events': 32 draws ok, partition ≈ {stream_est:.1} (truth {stream_truth:.1})"
    );
    anyhow::ensure!((stream_est - stream_truth).abs() / stream_truth < 6.0 * rel_std);
    drop(client);
    server.stop();

    // ---- Phase 6: replicated sampling survives a node kill. -------------
    let mut cluster = LocalCluster::start(3, &config())?;
    let mut cc = ClusterClient::connect_with(
        &cluster.addrs(),
        ReplicaConfig { replication: 2, write_quorum: 1, ..Default::default() },
    )?;
    for (c, v) in cats.iter().enumerate() {
        cc.upsert(&format!("cat{c}"), v.clone())?;
    }
    let healthy_draws = cc.sample(&QueryTarget::Keys(keys.clone()), 64, 9)?;
    let healthy_part = cc.partition(&QueryTarget::Keys(keys.clone()))?;
    anyhow::ensure!(
        healthy_draws == union_draws,
        "the cluster must draw exactly what the single node drew (same sketches, same seed)"
    );
    cluster.kill(1);
    anyhow::ensure!(
        cc.sample(&QueryTarget::Keys(keys.clone()), 64, 9)? == healthy_draws,
        "sample must fail over to live replicas with identical draws"
    );
    anyhow::ensure!(
        cc.partition(&QueryTarget::Keys(keys))? == healthy_part,
        "partition must fail over to live replicas with an identical estimate"
    );
    println!("cluster R=2, one node down: sample + partition answers IDENTICAL ✓");
    cluster.stop();

    println!("\nsampling_serve OK");
    Ok(())
}
