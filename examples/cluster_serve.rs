//! CLUSTER-SHARDED SERVING, END TO END: three real nodes, one client.
//!
//!   1. spawn a 3-node local cluster (real TCP on loopback), connect the
//!      scatter-gather `ClusterClient` (hello handshake: protocol, node
//!      ids, shared sketch config),
//!   2. ingest a corpus through the rendezvous partitioner (every key
//!      routed to its owning node) and answer `topk` queries by
//!      scatter → per-node LSH candidates → codec `sketch_fetch` →
//!      central `estimate_jp` re-rank → global k,
//!   3. snapshot every node, **kill one**, show the failure domain: `topk`
//!      keeps serving (degraded coverage, never a panic) while an `upsert`
//!      to the dead partition fails with a typed `NodeDown` error,
//!   4. restart the node cold, `restore` its snapshot (epoch bumps) — and
//!      verify the cluster answers every query with the exact rankings it
//!      gave before the failure,
//!   5. cluster-wide weighted cardinality: stream pushes partitioned by
//!      element id, per-site sketches merged centrally (§2.3).
//!
//! Runs offline in seconds; CI uses it as the cluster smoke test.
//!
//! ```bash
//! cargo run --release --example cluster_serve
//! ```

use fastgm::coordinator::cluster::{ClusterClient, ClusterError, LocalCluster};
use fastgm::coordinator::service::CoordinatorConfig;
use fastgm::data::corpus::Corpus;
use fastgm::sketch::SparseVector;
use fastgm::util::rng::SplitMix64;
use std::time::Instant;

const NODES: usize = 3;
const N_DOCS: usize = 240;
const K: usize = 128;
const SEED: u64 = 42;
const QUERIES: usize = 20;
const LIMIT: usize = 5;

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        k: K,
        seed: SEED,
        workers: 2,
        node_id: "site".into(),
        ..Default::default()
    }
}

/// Keep ~`keep` of the doc's mass, replace the rest with fresh ids.
fn perturb(rng: &mut SplitMix64, v: &SparseVector, keep: f64) -> SparseVector {
    let mut out = SparseVector::default();
    for (id, w) in v.positive() {
        if rng.next_f64() < keep {
            out.push(id, w);
        } else {
            out.push(rng.next_u64() | (1 << 63), w);
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    fastgm::util::logger::init();

    // ---- Phase 1: spawn the cluster, handshake. -------------------------
    let mut cluster = LocalCluster::start(NODES, &config())?;
    let mut cc = ClusterClient::connect(&cluster.addrs())?;
    println!("cluster up: {} nodes", cc.nodes());
    for i in 0..cc.nodes() {
        let h = cc.hello(i);
        println!("  {} @ {} (protocol v{}, epoch {})", h.node, cc.addr(i), h.protocol, h.epoch);
    }

    // ---- Phase 2: partitioned ingest + scatter-gather topk. -------------
    let corpus = Corpus::by_name("real-sim", 7).expect("real-sim corpus analog");
    let docs: Vec<SparseVector> = corpus.vectors(N_DOCS);
    let t0 = Instant::now();
    for (i, d) in docs.iter().enumerate() {
        cc.upsert(&format!("doc{i:03}"), d.clone())?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let sizes = cc.store_sizes();
    println!(
        "upserted {N_DOCS} docs in {dt:.2}s ({:.0} docs/s routed), occupancy: {:?}",
        N_DOCS as f64 / dt,
        sizes
    );
    let total: f64 = sizes.iter().filter_map(|(_, s)| *s).sum();
    anyhow::ensure!(total == N_DOCS as f64, "partitioned sizes must sum to the corpus");
    anyhow::ensure!(
        sizes.iter().all(|(_, s)| s.unwrap_or(0.0) > 0.0),
        "rendezvous partitioning left a node empty: {sizes:?}"
    );

    let mut rng = SplitMix64::new(2024);
    let targets: Vec<usize> = (0..QUERIES).map(|_| rng.next_range(0, N_DOCS - 1)).collect();
    let query_vecs: Vec<SparseVector> =
        targets.iter().map(|&t| perturb(&mut rng, &docs[t], 0.9)).collect();
    let t0 = Instant::now();
    let mut before = Vec::with_capacity(QUERIES);
    for q in &query_vecs {
        let (hits, stats) = cc.topk(q, LIMIT)?;
        anyhow::ensure!(stats.live == NODES, "all nodes should answer: {stats:?}");
        before.push(hits);
    }
    let qdt = t0.elapsed().as_secs_f64();
    let self_recall = targets
        .iter()
        .zip(&before)
        .filter(|(t, hits)| hits.first().map(|h| h.0 == format!("doc{t:03}")) == Some(true))
        .count();
    println!(
        "{QUERIES} scatter-gather top-{LIMIT} in {:.1} ms ({:.2} ms each), self-recall {self_recall}/{QUERIES}",
        qdt * 1e3,
        qdt * 1e3 / QUERIES as f64,
    );
    anyhow::ensure!(self_recall as f64 / QUERIES as f64 > 0.9, "self-recall too low");

    // ---- Phase 3: snapshot all, kill one, degrade. ----------------------
    let snap_dir = std::env::temp_dir();
    let mut snaps = Vec::new();
    for i in 0..NODES {
        let path = snap_dir
            .join(format!("fastgm-cluster-{}-{i}.fgms", std::process::id()))
            .to_string_lossy()
            .to_string();
        println!("{}", cc.snapshot_node(i, &path)?);
        snaps.push(path);
    }
    const VICTIM: usize = 1;
    println!("killing {} ...", cc.node_id(VICTIM));
    cluster.kill(VICTIM);
    // topk keeps serving — degraded coverage, never a panic.
    let (degraded, stats) = cc.topk(&query_vecs[0], LIMIT)?;
    println!(
        "degraded topk answered with {}/{} nodes live, {} hits",
        stats.live,
        stats.nodes,
        degraded.len()
    );
    anyhow::ensure!(stats.live == NODES - 1, "exactly one node should be down");
    // A write to the dead partition is a typed error.
    let dead_key = (0..)
        .map(|i| format!("probe{i}"))
        .find(|k| cc.owner(k) == VICTIM)
        .expect("some key lands on the victim");
    match cc.upsert(&dead_key, docs[0].clone()) {
        Err(ClusterError::NodeDown { node, .. }) => {
            println!("upsert '{dead_key}' → typed NodeDown({node}) ✓")
        }
        other => anyhow::bail!("expected NodeDown for '{dead_key}', got {other:?}"),
    }

    // ---- Phase 4: restart cold, restore, identical rankings. ------------
    cluster.restart(VICTIM)?;
    cc.reconnect(VICTIM, cluster.addr(VICTIM))?;
    println!("{}", cc.restore_node(VICTIM, &snaps[VICTIM])?);
    cc.reconnect(VICTIM, cluster.addr(VICTIM))?; // refresh hello: epoch bumped
    anyhow::ensure!(cc.hello(VICTIM).epoch == 1, "restore must bump the node epoch");
    let mut after = Vec::with_capacity(QUERIES);
    for q in &query_vecs {
        after.push(cc.topk(q, LIMIT)?.0);
    }
    anyhow::ensure!(
        before == after,
        "restored cluster ranked neighbors differently than before the failure"
    );
    println!("restored cluster reproduces all {QUERIES} rankings exactly ✓");

    // ---- Phase 5: §2.3 cardinality across sites. ------------------------
    let items: Vec<(u64, f64)> = (0..2000u64).map(|i| (i, 1.0)).collect();
    cc.push("pkts", &items)?;
    let est = cc.cardinality("pkts")?;
    let rel = (est - 2000.0).abs() / 2000.0;
    println!("cluster cardinality: {est:.1} (truth 2000, rel err {:.1}%)", rel * 100.0);
    anyhow::ensure!(rel < 0.3, "cardinality estimate out of bounds");

    cluster.stop();
    for p in snaps {
        std::fs::remove_file(p).ok();
    }
    println!("\ncluster_serve OK");
    Ok(())
}
