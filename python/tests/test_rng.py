"""Golden-value lock between the Python and Rust Direct-family RNGs.

The constants here are asserted verbatim in
``rust/src/util/rng.rs::tests::direct_family_golden``. If either
implementation changes, both test suites fail — the cross-layer sketch
consistency depends on it.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import direct_bits, direct_exp, direct_uniform, fmix32

U32 = st.integers(min_value=0, max_value=2**32 - 1)


def test_fmix32_golden():
    assert int(fmix32(jnp.uint32(0))) == 0
    assert int(fmix32(jnp.uint32(1))) == 0x514E28B7
    assert int(fmix32(jnp.uint32(0xDEADBEEF))) == 0x0DE5C6A9


def test_direct_bits_golden():
    # Same triples as the Rust test.
    assert int(direct_bits(0, 0, 0)) == 0x74B4A163
    assert int(direct_bits(42, 7, 1023)) == 0xDEFDEE35
    assert int(direct_bits(0xFFFFFFFF, 123456, 89)) == 0x48944F12


@settings(max_examples=200, deadline=None)
@given(seed=U32, i=U32, j=U32)
def test_uniform_open_interval(seed, i, j):
    u = float(direct_uniform(seed, i, j))
    assert 0.0 < u < 1.0


def test_exp_moments():
    i = jnp.arange(200_000, dtype=jnp.uint32)
    e = np.asarray(direct_exp(3, i, jnp.uint32(0)), dtype=np.float64)
    assert abs(e.mean() - 1.0) < 0.02
    assert abs(e.var() - 1.0) < 0.05
    assert (e > 0).all()


@settings(max_examples=50, deadline=None)
@given(seed=U32, i=U32, j=U32)
def test_bits_deterministic_and_seed_sensitive(seed, i, j):
    a = int(direct_bits(seed, i, j))
    assert a == int(direct_bits(seed, i, j))
    b = int(direct_bits(seed ^ 1, i, j))
    # Not a strict inequality law, but collision chance is 2^-32; with 50
    # examples a false failure is ~1e-8.
    assert a != b or seed == seed ^ 1


def test_vectorized_matches_scalar():
    i = jnp.arange(64, dtype=jnp.uint32)[:, None]
    j = jnp.arange(16, dtype=jnp.uint32)[None, :]
    m = direct_bits(9, i, j)
    for ii in (0, 7, 63):
        for jj in (0, 5, 15):
            assert int(m[ii, jj]) == int(direct_bits(9, ii, jj))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
