"""Layer-2 model graphs: composition, shape/dtype contracts, and the
Pallas-vs-pure-XLA ablation twin agreement. Also smoke-tests the AOT
lowering path (HLO text generation) without writing artifacts."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import to_hlo_text, variants
from compile.kernels.ref import sim_matrix_ref


def test_pallas_and_xla_variants_agree():
    rng = np.random.default_rng(3)
    v = rng.random((4, 128), dtype=np.float32)
    seed = jnp.asarray([11], jnp.uint32)
    y1, s1 = model.dense_sketch(32)(seed, jnp.asarray(v))
    y2, s2 = model.dense_sketch_xla(32)(seed, jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_sketch_sim_composes():
    rng = np.random.default_rng(4)
    vq = rng.random((2, 64), dtype=np.float32)
    vc = rng.random((8, 64), dtype=np.float32)
    seed = jnp.asarray([5], jnp.uint32)
    yq, sq, yc, sc, sim = model.sketch_sim(16)(seed, jnp.asarray(vq), jnp.asarray(vc))
    assert yq.shape == (2, 16) and sc.shape == (8, 16) and sim.shape == (2, 8)
    want = np.asarray(sim_matrix_ref(sq, sc))
    np.testing.assert_allclose(np.asarray(sim), want, atol=1e-6)
    # A vector is maximally similar to itself: sketch vq[0] as candidate too.
    yq2, sq2 = model.dense_sketch(16)(seed, jnp.asarray(vq))
    np.testing.assert_array_equal(np.asarray(sq2), np.asarray(sq))


def test_variants_table_is_well_formed():
    vs = variants()
    names = [v[0] for v in vs]
    assert len(set(names)) == len(names), "duplicate variant names"
    assert any(n.startswith("sketch_b8") for n in names)
    assert any(n.startswith("sketchxla") for n in names)
    assert any(n.startswith("simmat") for n in names)
    assert any(n.startswith("sketchsim") for n in names)


def test_hlo_text_lowering_smoke():
    # Lower the smallest variant to HLO text; must parse as HLO module text.
    name, fn, specs, _ = [v for v in variants() if v[0].startswith("simmat")][0]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    assert len(text) > 200
