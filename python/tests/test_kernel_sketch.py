"""Pallas gumbel_sketch kernel vs the pure-jnp oracle — THE Layer-1
correctness signal. Hypothesis sweeps shapes, seeds and weight patterns
(including zero entries and all-zero rows)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.gumbel_sketch import gumbel_sketch, pick_blocks
from compile.kernels.ref import gumbel_sketch_ref_k


def _assert_matches_ref(seed, v, k):
    y, s = gumbel_sketch(jnp.asarray([seed], jnp.uint32), v, k)
    yr, sr = gumbel_sketch_ref_k(seed, v, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6, atol=0)
    # Argmins must agree exactly wherever the row has a positive entry
    # (f32 race values tie with probability ~0); empty rows pin s = 0 in
    # both implementations.
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
    assert y.dtype == jnp.float32 and s.dtype == jnp.int32


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    n=st.sampled_from([16, 64, 128, 256]),
    k=st.sampled_from([8, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    data=st.data(),
)
def test_kernel_matches_ref(b, n, k, seed, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    v = rng.random((b, n), dtype=np.float32)
    # Sparsify: zero a random fraction.
    mask = rng.random((b, n)) < data.draw(st.floats(0.0, 0.9))
    v = np.where(mask, 0.0, v).astype(np.float32)
    _assert_matches_ref(seed, jnp.asarray(v), k)


def test_all_zero_row():
    v = jnp.zeros((2, 32), jnp.float32)
    y, s = gumbel_sketch(jnp.asarray([7], jnp.uint32), v, 16)
    assert np.isinf(np.asarray(y)).all()
    assert (np.asarray(s) == 0).all()
    yr, sr = gumbel_sketch_ref_k(7, v, 16)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_single_positive_element_wins_everywhere():
    v = np.zeros((1, 64), np.float32)
    v[0, 17] = 2.5
    y, s = gumbel_sketch(jnp.asarray([3], jnp.uint32), jnp.asarray(v), 32)
    assert (np.asarray(s) == 17).all()
    assert (np.asarray(y) > 0).all() and np.isfinite(np.asarray(y)).all()


def test_scale_invariance_of_argmax():
    rng = np.random.default_rng(0)
    v = rng.random((4, 128), dtype=np.float32)
    _, s1 = gumbel_sketch(jnp.asarray([1], jnp.uint32), jnp.asarray(v), 64)
    y1, _ = gumbel_sketch(jnp.asarray([1], jnp.uint32), jnp.asarray(v), 64)
    y2, s2 = gumbel_sketch(jnp.asarray([1], jnp.uint32), jnp.asarray(4.0 * v), 64)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(y1) / 4.0, np.asarray(y2), rtol=1e-6)


def test_consistency_across_batches():
    # The same row sketched in different batch positions gives identical
    # registers (the RNG depends only on (seed, i, j)).
    rng = np.random.default_rng(5)
    row = rng.random((1, 64), dtype=np.float32)
    other = rng.random((3, 64), dtype=np.float32)
    batch = np.concatenate([other, row], axis=0)
    y_solo, s_solo = gumbel_sketch(jnp.asarray([9], jnp.uint32), jnp.asarray(row), 32)
    y_b, s_b = gumbel_sketch(jnp.asarray([9], jnp.uint32), jnp.asarray(batch), 32)
    np.testing.assert_array_equal(np.asarray(s_solo)[0], np.asarray(s_b)[3])
    np.testing.assert_allclose(np.asarray(y_solo)[0], np.asarray(y_b)[3], rtol=1e-7)


def test_pick_blocks_divides():
    for (b, n, k) in [(1, 16, 8), (8, 1024, 256), (5, 96, 24), (32, 4096, 1024)]:
        bb, bn, bk = pick_blocks(b, n, k)
        assert b % bb == 0 and n % bn == 0 and k % bk == 0
        assert bb >= 1 and bn >= 1 and bk >= 1


def test_argmax_distribution_is_weight_proportional():
    # Statistical sanity: heavy element wins proportionally more registers.
    v = np.zeros((1, 8), np.float32)
    v[0, :3] = [0.6, 0.3, 0.1]
    _, s = gumbel_sketch(jnp.asarray([123], jnp.uint32), jnp.asarray(v), 2048)
    s = np.asarray(s)[0]
    for i, w in enumerate([0.6, 0.3, 0.1]):
        p = (s == i).mean()
        assert abs(p - w) < 0.05, f"element {i}: p={p} want {w}"
