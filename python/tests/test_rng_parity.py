"""Cross-language lock on the Ordered-family RNG substrate.

``rust/tests/fixtures/rng_parity.json`` is asserted from both sides:
``rust/tests/rng_parity.rs`` checks that ``util::rng`` + ``order_stats``
reproduce it, and this test checks that the pure-Python reference
(``rng_reference.py``, which generated it) still does. If either language's
implementation changes, its suite fails against the frozen fixture — the
same scheme ``test_rng.py`` uses for the Direct-family constants.

Pure stdlib: no jax required.
"""

import json
import math

import pytest

from rng_reference import (
    ElementRace,
    SplitMix64,
    direct_bits,
    fixture_path,
    fmix32,
    fmix64,
    generate_fixture,
    self_check,
)


@pytest.fixture(scope="module")
def fixture():
    with open(fixture_path()) as f:
        return json.load(f)


def test_reference_self_check():
    # The constants shared with rust/src/util/rng.rs and test_rng.py.
    self_check()


def test_fmix_tables(fixture):
    for x, want in fixture["fmix32"]:
        assert fmix32(int(x)) == int(want)
    for x, want in fixture["fmix64"]:
        assert fmix64(int(x)) == int(want)


def test_direct_bits_table(fixture):
    for seed, i, j, want in fixture["direct_bits"]:
        assert direct_bits(int(seed), int(i), int(j)) == int(want)


def test_splitmix_streams(fixture):
    for case in fixture["splitmix64"]:
        seed = int(case["seed"])
        r = SplitMix64(seed)
        for want in case["u64"]:
            assert r.next_u64() == int(want)
        r = SplitMix64(seed)
        for want in case["f64"]:
            # Dyadic arithmetic: exact across languages.
            assert r.next_f64() == float(want)


def test_for_element_keying(fixture):
    for case in fixture["for_element"]:
        r = SplitMix64.for_element(int(case["seed"]), int(case["element"]))
        assert r.next_u64() == int(case["first_u64"])


def test_batched_block_streams(fixture):
    """The reference stream for the Rust SIMD kernel layer: uniforms are
    dyadic (exact), exponentials go through ``log`` (1e-12 relative)."""
    for case in fixture["batched_blocks"]:
        seed = int(case["seed"])
        u = SplitMix64(seed)
        for want in case["uniform"]:
            assert u.next_f64() == float(want)
        e = SplitMix64(seed)
        for want in case["exp"]:
            assert math.isclose(e.next_exp(), float(want), rel_tol=1e-12)


def test_element_race_streams(fixture):
    for case in fixture["element_race"]:
        race = ElementRace(
            int(case["seed"]), int(case["element"]), float(case["w"]), case["k"]
        )
        pairs = race.drain()
        assert [c for (_, c) in pairs] == case["registers"]
        for (b, _), want in zip(pairs, case["arrivals"]):
            # ln() is libm-dependent; allow rounding noise only.
            assert math.isclose(b, float(want), rel_tol=1e-12)
        # Sanity: arrivals ascend and registers form a permutation.
        times = [b for (b, _) in pairs]
        assert times == sorted(times)
        assert sorted(c for (_, c) in pairs) == list(range(case["k"]))


def test_fixture_is_current():
    """Regenerating must reproduce the checked-in fixture (up to float
    formatting, which repr makes canonical)."""
    with open(fixture_path()) as f:
        on_disk = json.load(f)
    fresh = generate_fixture()
    assert set(fresh) == set(on_disk)
    for key in ("fmix32", "fmix64", "direct_bits", "splitmix64", "for_element"):
        assert fresh[key] == on_disk[key], f"section {key} drifted"
    for a, b in zip(fresh["element_race"], on_disk["element_race"]):
        assert a["registers"] == b["registers"]
        for x, y in zip(a["arrivals"], b["arrivals"]):
            assert math.isclose(float(x), float(y), rel_tol=1e-12)
    for a, b in zip(fresh["batched_blocks"], on_disk["batched_blocks"]):
        assert a["seed"] == b["seed"]
        assert a["uniform"] == b["uniform"], "uniform blocks are dyadic-exact"
        for x, y in zip(a["exp"], b["exp"]):
            assert math.isclose(float(x), float(y), rel_tol=1e-12)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
