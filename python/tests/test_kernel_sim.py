"""Pallas sim_matrix kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import sim_matrix_ref
from compile.kernels.sim_matrix import sim_matrix


@settings(max_examples=25, deadline=None)
@given(
    q=st.sampled_from([1, 4, 16]),
    c=st.sampled_from([1, 8, 32, 128]),
    k=st.sampled_from([8, 64, 256]),
    vocab=st.sampled_from([2, 16, 1 << 20]),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref(q, c, k, vocab, seed):
    rng = np.random.default_rng(seed)
    sq = rng.integers(0, vocab, size=(q, k), dtype=np.int32)
    sc = rng.integers(0, vocab, size=(c, k), dtype=np.int32)
    got = np.asarray(sim_matrix(jnp.asarray(sq), jnp.asarray(sc)))
    want = np.asarray(sim_matrix_ref(jnp.asarray(sq), jnp.asarray(sc)))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)
    assert got.shape == (q, c)


def test_identical_signatures_score_one():
    rng = np.random.default_rng(1)
    s = rng.integers(0, 1000, size=(4, 64), dtype=np.int32)
    out = np.asarray(sim_matrix(jnp.asarray(s), jnp.asarray(s)))
    np.testing.assert_allclose(np.diag(out), 1.0)


def test_disjoint_signatures_score_zero():
    a = np.zeros((2, 32), np.int32)
    b = np.ones((3, 32), np.int32)
    out = np.asarray(sim_matrix(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(out, 0.0)


def test_half_overlap():
    k = 64
    a = np.zeros((1, k), np.int32)
    b = np.zeros((1, k), np.int32)
    b[0, : k // 2] = 7
    out = np.asarray(sim_matrix(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(out, 0.5)
