"""Pure-Python reference of the Rust RNG substrate, for cross-language parity.

Mirrors, operation for operation:

* ``rust/src/util/rng.rs`` — ``fmix32``/``fmix64``, the Direct-family
  counter RNG ``direct_bits``, and the ``SplitMix64`` stream (``next_u64``,
  ``next_u32``, ``next_f64``, ``next_range``, ``for_element``);
* ``rust/src/sketch/order_stats.rs`` — the ascending-exponential
  ``ElementRace`` with its streamed Fisher-Yates register assignment (a
  dense permutation here; the Rust side's lazy permutation is
  observationally identical, which is exactly what the parity test checks).

Running this module regenerates ``rust/tests/fixtures/rng_parity.json``,
the fixture asserted by BOTH ``python/tests/test_rng_parity.py`` and
``rust/tests/rng_parity.rs``. Integer outputs must match exactly; arrival
times involve ``log`` and are compared to 1e-12 relative (libm rounding is
the only permitted divergence).

All u64 values are serialized as decimal strings (JSON numbers are f64 and
would silently truncate above 2^53 — the same rule the wire protocol uses);
f64 values are serialized with ``repr`` (17 significant digits, lossless).
"""

import json
import math
import os

MASK32 = (1 << 32) - 1
MASK64 = (1 << 64) - 1
GOLDEN64 = 0x9E3779B97F4A7C15
DIRECT_SALT = 0xA0761D64


def fmix32(h):
    h &= MASK32
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & MASK32
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & MASK32
    h ^= h >> 16
    return h


def fmix64(h):
    h &= MASK64
    h ^= h >> 33
    h = (h * 0xFF51AFD7ED558CCD) & MASK64
    h ^= h >> 33
    h = (h * 0xC4CEB9FE1A85EC53) & MASK64
    h ^= h >> 33
    return h


def direct_bits(seed, i, j):
    h = fmix32(seed ^ DIRECT_SALT ^ ((i * 0x9E3779B1) & MASK32))
    return fmix32(h ^ ((j * 0x85EBCA77) & MASK32))


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & MASK64

    @classmethod
    def for_element(cls, seed, element):
        return cls(fmix64((element + GOLDEN64) & MASK64) ^ seed)

    def next_u64(self):
        self.state = (self.state + GOLDEN64) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def next_u32(self):
        return self.next_u64() >> 32

    def next_f64(self):
        # ((bits >> 12) + 0.5) * 2^-52: pure dyadic arithmetic, so this is
        # bit-exact across languages (no libm involved).
        return ((self.next_u64() >> 12) + 0.5) * (1.0 / 4503599627370496.0)

    def next_range(self, lo, hi):
        span = hi - lo + 1
        return lo + ((self.next_u32() * span) >> 32)

    def next_exp(self):
        # EXP(1) via inversion; ``log`` is the only libm call, so parity
        # with Rust's ``-next_f64().ln()`` is 1e-12-relative, not bitwise.
        return -math.log(self.next_f64())


class ElementRace:
    """Queue Q_i: k EXP(w) arrivals in ascending order + register marks."""

    def __init__(self, seed, element, w, k):
        self.rng = SplitMix64.for_element(seed, element)
        self.inv_w = 1.0 / w
        self.k = k
        self.z = 0
        self.b = 0.0
        self.perm = list(range(k))

    def next(self):
        if self.z >= self.k:
            return None
        remaining = float(self.k - self.z)
        self.z += 1
        u = self.rng.next_f64()
        self.b += self.inv_w * (-math.log(u)) / remaining
        z0 = self.z - 1
        j = self.rng.next_range(z0, self.k - 1)
        self.perm[z0], self.perm[j] = self.perm[j], self.perm[z0]
        return (self.b, self.perm[z0])

    def drain(self):
        out = []
        while True:
            t = self.next()
            if t is None:
                return out
            out.append(t)


def self_check():
    """The constants pinned in rust/src/util/rng.rs and test_rng.py —
    if these hold, the Python port is faithful to the Rust arithmetic."""
    assert fmix32(0) == 0
    assert fmix32(1) == 0x514E28B7
    assert fmix32(0xDEADBEEF) == 0x0DE5C6A9
    assert direct_bits(0, 0, 0) == 0x74B4A163
    assert direct_bits(42, 7, 1023) == 0xDEFDEE35
    assert direct_bits(0xFFFFFFFF, 123456, 89) == 0x48944F12
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F


def generate_fixture():
    self_check()
    fix = {}

    fix["fmix32"] = [[str(x), str(fmix32(x))] for x in [0, 1, 2, 0xDEADBEEF, 0xFFFFFFFF, 123456789]]
    fix["fmix64"] = [
        [str(x), str(fmix64(x))]
        for x in [0, 1, GOLDEN64, 0xDEADBEEFCAFEBABE, MASK64, 9007199254740993]
    ]
    fix["direct_bits"] = [
        [str(s), str(i), str(j), str(direct_bits(s, i, j))]
        for (s, i, j) in [
            (0, 0, 0),
            (42, 7, 1023),
            (0xFFFFFFFF, 123456, 89),
            (1, 0, 1),
            (7, 4294967295, 4294967295),
            (305419896, 99, 3),
        ]
    ]

    fix["splitmix64"] = []
    for seed in [0, 1, 42, 0xFA576D5E, MASK64]:
        u = SplitMix64(seed)
        f = SplitMix64(seed)
        fix["splitmix64"].append(
            {
                "seed": str(seed),
                "u64": [str(u.next_u64()) for _ in range(8)],
                "f64": [repr(f.next_f64()) for _ in range(4)],
            }
        )

    fix["for_element"] = [
        {"seed": str(seed), "element": str(elem), "first_u64": str(SplitMix64.for_element(seed, elem).next_u64())}
        for (seed, elem) in [(0, 1), (0, 2), (42, 0), (7, MASK64), (MASK64, 12345)]
    ]

    # Batched-variate blocks: the reference stream for the SIMD kernel
    # layer (rust/src/sketch/kernels.rs). The Rust side fills these via
    # fill_uniform_block / fill_exp_block on BOTH backends; uniforms are
    # dyadic (bit-exact across languages), exponentials are 1e-12-relative.
    # 16 draws straddle the 4-wide AVX2 body and its scalar tail.
    fix["batched_blocks"] = []
    for seed in [0, 42, MASK64]:
        u = SplitMix64(seed)
        e = SplitMix64(seed)
        fix["batched_blocks"].append(
            {
                "seed": str(seed),
                "uniform": [repr(u.next_f64()) for _ in range(16)],
                "exp": [repr(e.next_exp()) for _ in range(16)],
            }
        )

    fix["element_race"] = []
    for (seed, elem, w, k) in [
        (7, 42, 0.5, 16),
        (1, 9007199254740993, 2.0, 8),
        (0xFA576D5E, 3, 1.0, 32),
        (9, 5, 0.25, 1),
    ]:
        race = ElementRace(seed, elem, w, k)
        pairs = race.drain()
        fix["element_race"].append(
            {
                "seed": str(seed),
                "element": str(elem),
                "w": repr(w),
                "k": k,
                "registers": [c for (_, c) in pairs],
                "arrivals": [repr(b) for (b, _) in pairs],
            }
        )
    return fix


def fixture_path():
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(
        os.path.join(here, "..", "..", "rust", "tests", "fixtures", "rng_parity.json")
    )


if __name__ == "__main__":
    path = fixture_path()
    with open(path, "w") as f:
        json.dump(generate_fixture(), f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
