"""Layer-2 JAX model: the compute graphs the coordinator AOT-loads.

Three graphs, each lowered to HLO text by ``compile/aot.py``:

* ``dense_sketch``     — Pallas dense Gumbel-Max sketch (the accelerator
                         path for dense, low-dimensional batches).
* ``dense_sketch_xla`` — same computation as pure jnp (the materialize-
                         everything baseline; the `ablation-accel`
                         experiment compares the two).
* ``sim_matrix``       — Pallas pairwise similarity of ArgMax signatures.
* ``sketch_sim``       — fused end-to-end graph: sketch a query batch and a
                         candidate batch, then score all pairs; shows the
                         kernels composing inside one XLA module.

All functions are shape-monomorphic at lowering time (PJRT AOT requires
static shapes); the Rust runtime buckets/pads requests to the compiled
shapes (see ``rust/src/runtime``).
"""

import jax.numpy as jnp

from .kernels.gumbel_sketch import gumbel_sketch
from .kernels.ref import gumbel_sketch_ref_k
from .kernels.sim_matrix import sim_matrix as sim_matrix_kernel


def dense_sketch(k):
    """Returns fn(seed [1] u32, v [B,N] f32) -> (y [B,k] f32, s [B,k] i32)."""

    def fn(seed, v):
        return gumbel_sketch(seed, v, k)

    return fn


def dense_sketch_xla(k):
    """Pure-XLA baseline of the same computation (no Pallas)."""

    def fn(seed, v):
        return gumbel_sketch_ref_k(seed, v, k)

    return fn


def sim_matrix(sq, sc):
    """fn(sq [Q,K] i32, sc [C,K] i32) -> [Q,C] f32."""
    return sim_matrix_kernel(sq, sc)


def sketch_sim(k):
    """Fused graph: sketch queries and candidates, then score all pairs.

    fn(seed, vq [Q,N], vc [C,N]) -> (yq, sq, yc, sc, sim [Q,C])
    """

    def fn(seed, vq, vc):
        yq, sq = gumbel_sketch(seed, vq, k)
        yc, sc = gumbel_sketch(seed, vc, k)
        sim = sim_matrix_kernel(sq, sc)
        return yq, sq, yc, sc, sim

    return fn
