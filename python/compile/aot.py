"""AOT pipeline: lower every model variant to HLO **text** + a manifest.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that the
xla_extension 0.5.1 behind the Rust ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits ``<name>.hlo.txt`` per variant plus ``manifest.json`` describing the
input/output shapes and dtypes the Rust runtime must honor.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, builder(k) -> fn, example input specs)
# Shapes are the coordinator's batch buckets (rust/src/runtime/accel.rs).
_U32 = jnp.uint32
_F32 = jnp.float32
_I32 = jnp.int32


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def variants():
    """The AOT compilation matrix."""
    out = []
    for (b, n, k) in [(8, 1024, 256), (32, 1024, 256), (8, 4096, 1024)]:
        out.append(
            (
                f"sketch_b{b}_n{n}_k{k}",
                model.dense_sketch(k),
                [_spec((1,), _U32), _spec((b, n), _F32)],
                "pallas",
            )
        )
    # Pure-XLA ablation twin of the first bucket.
    b, n, k = 8, 1024, 256
    out.append(
        (
            f"sketchxla_b{b}_n{n}_k{k}",
            model.dense_sketch_xla(k),
            [_spec((1,), _U32), _spec((b, n), _F32)],
            "xla",
        )
    )
    # Similarity matrix over signatures.
    q, c, k = 16, 128, 256
    out.append(
        (
            f"simmat_q{q}_c{c}_k{k}",
            model.sim_matrix,
            [_spec((q, k), _I32), _spec((c, k), _I32)],
            "pallas",
        )
    )
    # Fused end-to-end graph.
    q, c, n, k = 8, 64, 1024, 256
    out.append(
        (
            f"sketchsim_q{q}_c{c}_n{n}_k{k}",
            model.sketch_sim(k),
            [_spec((1,), _U32), _spec((q, n), _F32), _spec((c, n), _F32)],
            "pallas",
        )
    )
    return out


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="build a single variant by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, fn, specs, kind in variants():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = lowered.out_info
        # out_info is a pytree of ShapeDtypeStructs (tuple for multi-output).
        flat_outs, _ = jax.tree_util.tree_flatten(outs)
        manifest.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": kind,
                "inputs": [
                    {"shape": list(s.shape), "dtype": s.dtype.name} for s in specs
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": jnp.dtype(o.dtype).name}
                    for o in flat_outs
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump({"version": 1, "artifacts": manifest}, f, indent=2)
    print(f"wrote {mpath} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
