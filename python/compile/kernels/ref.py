"""Pure-jnp reference oracles for the Pallas kernels, plus the Direct-family
counter RNG shared bit-for-bit with the Rust coordinator
(``rust/src/util/rng.rs``). Golden-value tests on both sides pin the two
implementations to the same constants (see ``python/tests/test_rng.py``).

Everything here is build-time only: the AOT pipeline (``compile/aot.py``)
lowers the model to HLO text once; Python never runs on the request path.
"""

import jax.numpy as jnp

# Constants mirrored in rust/src/util/rng.rs (Direct family).
_DIRECT_SALT = 0xA0761D64
_MUL_I = 0x9E3779B1
_MUL_J = 0x85EBCA77


def fmix32(h):
    """murmur3 32-bit finalizer over uint32 arrays (wrapping arithmetic)."""
    h = jnp.asarray(h, jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def direct_bits(seed, i, j):
    """32 uniform bits for cell (i, j) under ``seed`` — two chained
    finalizer rounds, identical to ``rng::direct_bits`` in Rust."""
    seed = jnp.asarray(seed, jnp.uint32)
    i = jnp.asarray(i, jnp.uint32)
    j = jnp.asarray(j, jnp.uint32)
    h = fmix32(seed ^ jnp.uint32(_DIRECT_SALT) ^ (i * jnp.uint32(_MUL_I)))
    return fmix32(h ^ (j * jnp.uint32(_MUL_J)))


def direct_uniform(seed, i, j):
    """Uniform f32 in the open interval (0, 1): ((bits>>9)+0.5) * 2^-23."""
    bits = direct_bits(seed, i, j)
    return ((bits >> 9).astype(jnp.float32) + jnp.float32(0.5)) * jnp.float32(
        1.0 / 8388608.0
    )


def direct_exp(seed, i, j):
    """EXP(1) draw for cell (i, j): -ln(U), strictly positive and finite."""
    return -jnp.log(direct_uniform(seed, i, j))


def gumbel_sketch_ref_k(seed, v, k):
    """Dense Gumbel-Max sketch oracle: y_j = min_i -ln(a_ij)/v_i over the
    positive entries; s_j the argmin (0 when the whole row is empty).

    v: [B, N] f32. Returns (y [B,k] f32, s [B,k] int32).
    """
    seed = jnp.asarray(seed, jnp.uint32).reshape(()).astype(jnp.uint32)
    b, n = v.shape
    i = jnp.arange(n, dtype=jnp.uint32)[:, None]
    j = jnp.arange(k, dtype=jnp.uint32)[None, :]
    e = direct_exp(seed, i, j)  # [N, K]
    cand = jnp.where(
        v[:, :, None] > 0, e[None, :, :] / v[:, :, None], jnp.float32(jnp.inf)
    )  # [B, N, K]
    y = cand.min(axis=1)
    s = cand.argmin(axis=1).astype(jnp.int32)
    return y, s


def sim_matrix_ref(sq, sc):
    """Mean register-equality matrix: out[q, c] = (1/K) Σ_j [sq[q,j]==sc[c,j]].

    sq: [Q, K] int32, sc: [C, K] int32. Returns [Q, C] float32.
    """
    eq = (sq[:, None, :] == sc[None, :, :]).astype(jnp.float32)
    return eq.mean(axis=2)
