"""Layer-1 Pallas kernel: sketch-equality similarity matrix.

Given ArgMax signatures ``Sq [Q, K]`` and ``Sc [C, K]`` (int32 register
ids), computes the probability-Jaccard estimate matrix

    out[q, c] = (1/K) Σ_j [ Sq[q, j] == Sc[c, j] ]

tiled like a matmul: grid over (Q/bq, C/bc) output tiles, reduction over K
in bkc-sized chunks held in VMEM. Equality-compare + accumulate runs on the
VPU; an MXU formulation would need n-wide one-hot expansions of register
ids (infeasible for large id spaces) — the trade-off DESIGN.md
§Hardware-Adaptation calls out.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_kernel(sq_ref, sc_ref, o_ref, *, bkc, k):
    def body(c, acc):
        j0 = c * bkc
        a = sq_ref[:, pl.ds(j0, bkc)]  # [bq, bkc]
        b = sc_ref[:, pl.ds(j0, bkc)]  # [bc, bkc]
        eq = (a[:, None, :] == b[None, :, :]).astype(jnp.float32)
        return acc + eq.sum(axis=2)

    bq = sq_ref.shape[0]
    bc = sc_ref.shape[0]
    acc = jax.lax.fori_loop(0, k // bkc, body, jnp.zeros((bq, bc), jnp.float32))
    o_ref[...] = acc * jnp.float32(1.0 / k)


def pick_blocks(q, c, k):
    def largest_div(x, cap):
        d = min(x, cap)
        while x % d:
            d -= 1
        return d

    return largest_div(q, 16), largest_div(c, 128), largest_div(k, 128)


def sim_matrix(sq, sc, *, interpret=True):
    """Pairwise J_P estimates between two signature batches.

    sq: [Q, K] int32, sc: [C, K] int32 → [Q, C] float32.
    """
    q, k = sq.shape
    c, k2 = sc.shape
    assert k == k2, f"signature lengths differ: {k} vs {k2}"
    bq, bc, bkc = pick_blocks(q, c, k)
    kernel = functools.partial(_sim_kernel, bkc=bkc, k=k)
    return pl.pallas_call(
        kernel,
        grid=(q // bq, c // bc),
        in_specs=[
            pl.BlockSpec((bq, k), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((bc, k), lambda qi, ci: (ci, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda qi, ci: (qi, ci)),
        out_shape=jax.ShapeDtypeStruct((q, c), jnp.float32),
        interpret=interpret,
    )(sq, sc)
