"""Layer-1 Pallas kernel: dense batched Gumbel-Max sketch.

Computes, for a batch of dense weight rows ``V [B, N]`` and sketch length
``K``, the registers

    Y[b, j] = min_i  -ln(a_ij) / V[b, i]      (over V[b, i] > 0)
    S[b, j] = argmin_i ...                     (0 if the row is empty)

with the Direct-family counter RNG generated *inside* the kernel — no
[N, K] random matrix ever touches HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
``(B/bb, K/bk)``; each program keeps its V row-block and one [bn, bk]
race-variable tile in VMEM and reduces over N in a ``fori_loop`` — the
HBM↔VMEM schedule a CUDA version would express with threadblocks is the
BlockSpec + index_map here. The min/argmin accumulator lives in registers
(loop carry). This is a VPU-bound elementwise/reduction kernel; the MXU has
no min-plus mode, so the roofline is memory bandwidth on V (see DESIGN.md
§Perf for the VMEM/utilization estimate).

Must be lowered with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import direct_exp


def _sketch_kernel(seed_ref, v_ref, y_ref, s_ref, *, bn, bk, n):
    """One (bb × bk) output tile; loops the N axis in bn-sized chunks."""
    ki = pl.program_id(1)
    seed = seed_ref[0]
    bb = v_ref.shape[0]
    j = (ki * bk + jnp.arange(bk, dtype=jnp.uint32))[None, :]  # [1, bk]

    def body(c, carry):
        y, s = carry
        i0 = c * bn
        i = (i0.astype(jnp.uint32) + jnp.arange(bn, dtype=jnp.uint32))[:, None]
        e = direct_exp(seed, i, j)  # [bn, bk] — generated in VMEM/registers
        v = v_ref[:, pl.ds(i0, bn)]  # [bb, bn]
        cand = jnp.where(
            v[:, :, None] > 0, e[None, :, :] / v[:, :, None], jnp.float32(jnp.inf)
        )  # [bb, bn, bk]
        cmin = cand.min(axis=1)
        carg = cand.argmin(axis=1).astype(jnp.int32) + i0.astype(jnp.int32)
        upd = cmin < y
        return jnp.where(upd, cmin, y), jnp.where(upd, carg, s)

    y0 = jnp.full((bb, bk), jnp.inf, jnp.float32)
    s0 = jnp.zeros((bb, bk), jnp.int32)
    y, s = jax.lax.fori_loop(0, n // bn, body, (y0, s0))
    y_ref[...] = y
    s_ref[...] = s


def pick_blocks(b, n, k):
    """Block sizes: bb×bn×bk ≈ 128 KiB f32 tile, divisibility enforced."""

    def largest_div(x, cap):
        d = min(x, cap)
        while x % d:
            d -= 1
        return d

    bb = largest_div(b, 8)
    bn = largest_div(n, 128)
    bk = largest_div(k, 128)
    return bb, bn, bk


def gumbel_sketch(seed, v, k, *, interpret=True):
    """Batched dense Gumbel-Max sketch via Pallas.

    Args:
      seed: shape-(1,) uint32 array.
      v: [B, N] float32 weights (non-positive entries are absent).
      k: sketch length.

    Returns: (y [B,k] float32, s [B,k] int32).
    """
    b, n = v.shape
    bb, bn, bk = pick_blocks(b, n, k)
    kernel = functools.partial(_sketch_kernel, bn=bn, bk=bk, n=n)
    return pl.pallas_call(
        kernel,
        grid=(b // bb, k // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki: (0,)),
            pl.BlockSpec((bb, n), lambda bi, ki: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bk), lambda bi, ki: (bi, ki)),
            pl.BlockSpec((bb, bk), lambda bi, ki: (bi, ki)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(seed, jnp.uint32).reshape(1), v)
